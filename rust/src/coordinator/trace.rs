//! Trace record/replay: deterministic regression gates over the
//! serving stack.
//!
//! The paper's Tables 2–5 are one-shot measurements; this module turns
//! the reproduction's serving surface into a **replayable** one. A
//! [`TraceRecorder`] armed on
//! [`ServiceSpec::recorder`](super::service::ServiceSpec::recorder)
//! captures every
//! dispatch at the coordinator boundary — operator, shape, plane
//! payload (inline bits, a content fingerprint, or a generator seed),
//! arrival offset, deadline, tenant and traffic class — into a compact
//! versioned binary trace ([`Trace`]). [`replay`] then re-drives any
//! trace against an arbitrary shard-spec/routing/fuse/cache
//! configuration at 1×/N× speed and produces a [`ReplayReport`]:
//! per-op latency percentiles, padding waste, cache hit rate,
//! shed/denial counts and an FNV results checksum.
//!
//! **Recording is invisible.** The hook runs before the cache lookup,
//! before the observatory sampler ticks and before the routing policy
//! sees the request; it appends to the recorder's own buffer and never
//! touches shard telemetry (attempts/samples), queue depths or the
//! sampler — the same isolation contract the result cache and the
//! observatory mirrors obey, pinned by `tests/replay.rs`. Past its
//! byte budget the recorder **drops, never blocks**: an inline record
//! that would overflow degrades to a fingerprint-only record, and a
//! record that still would not fit is counted and discarded.
//!
//! **Replay is deterministic.** Arrival *gaps* are scaled by the
//! replay rate on a virtual clock (`virtual_ns = arrival_ns / rate`),
//! but deadlines and cancel offsets are applied **unscaled** — a
//! request recorded with a zero deadline misses at any speed, and a
//! cancel-at-dispatch request resolves `Cancelled` at any speed, so
//! verdicts are speed-robust. Replies are bit-identical regardless of
//! routing, fusion packing or cache residency (the fusion stage's
//! slice-back contract), so the folded results checksum
//! ([`ReplayReport::results_fnv`] — verdict code plus per-reply FNV,
//! folded in **record order**, independent of completion order) is
//! identical run over run and config over config. The CI replay gate
//! asserts exactly that over a committed golden trace.
//!
//! The byte grammar is pinned (`FFTR` v1, little-endian; see
//! `DESIGN.md` §11): decoding is total — truncated or corrupt bytes
//! fail with a typed [`TraceError`], never a panic — and encoding is
//! canonical, so decode∘encode is the identity on bytes (pinned by
//! `tests/trace_codec.rs`).

use super::plan::Plan;
use super::service::Service;
use crate::backend::fingerprint::{FNV_OFFSET, FNV_PRIME};
use crate::backend::{fingerprint, Op, ServiceError};
use crate::harness::workload;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Trace file magic: `FFTR` (float-float trace).
pub const TRACE_MAGIC: [u8; 4] = *b"FFTR";
/// Current trace format version.
pub const TRACE_VERSION: u16 = 1;
/// Header flag bit 0: every record carries inline planes, so a replay
/// can reproduce the recorded session bit for bit.
pub const FLAG_ALL_INLINE: u16 = 1;

/// Sentinel for "no deadline" / "never cancelled" nanosecond fields.
pub const NS_NONE: u64 = u64::MAX;

/// Hard per-record lane cap: decode refuses anything larger before
/// allocating, so a corrupt length field cannot OOM the process.
pub const MAX_LANES: u32 = 1 << 27;

/// Traffic-class codes carried per record (the coordinator cannot see
/// `net::Class`, so the wire layer maps into these).
pub const CLASS_UNSPECIFIED: u8 = 0;
pub const CLASS_INTERACTIVE: u8 = 1;
pub const CLASS_STANDARD: u8 = 2;
pub const CLASS_BULK: u8 = 3;
const CLASS_MAX: u8 = CLASS_BULK;

/// Typed trace codec failures — decoding is total, never a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceError {
    /// The first four bytes are not `FFTR`.
    BadMagic,
    /// Unknown format version.
    BadVersion(u16),
    /// Unknown header flag bits, or a flag that contradicts the
    /// records (canonical encodings derive flags from content).
    BadFlags(u16),
    /// The buffer ended inside the named field.
    Truncated(&'static str),
    /// Operator index outside the catalogue.
    BadOp(u8),
    /// Traffic-class code outside the known set.
    BadClass(u8),
    /// Verdict code outside the known set.
    BadVerdict(u8),
    /// Payload-kind code outside the known set.
    BadPayloadKind(u8),
    /// Tenant bytes are not UTF-8.
    BadTenant,
    /// Inline payload's plane count disagrees with the operator arity.
    ArityMismatch { op: Op, got: u8 },
    /// A record declared zero lanes.
    ZeroLanes,
    /// A record declared more lanes than [`MAX_LANES`].
    TooLarge { lanes: u32 },
    /// Well-formed records followed by unconsumed bytes.
    TrailingBytes(usize),
    /// Filesystem failure on [`Trace::save`] / [`Trace::load`].
    Io(String),
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::BadMagic => write!(f, "bad trace magic (want FFTR)"),
            TraceError::BadVersion(v) => write!(f, "unsupported trace version {v}"),
            TraceError::BadFlags(x) => write!(f, "bad trace flags {x:#06x}"),
            TraceError::Truncated(what) => write!(f, "trace truncated in {what}"),
            TraceError::BadOp(c) => write!(f, "bad op code {c}"),
            TraceError::BadClass(c) => write!(f, "bad class code {c}"),
            TraceError::BadVerdict(c) => write!(f, "bad verdict code {c}"),
            TraceError::BadPayloadKind(c) => write!(f, "bad payload kind {c}"),
            TraceError::BadTenant => write!(f, "tenant bytes are not UTF-8"),
            TraceError::ArityMismatch { op, got } => {
                write!(f, "inline payload has {got} planes, {op} wants {}", op.n_in())
            }
            TraceError::ZeroLanes => write!(f, "record declares zero lanes"),
            TraceError::TooLarge { lanes } => {
                write!(f, "record declares {lanes} lanes (cap {MAX_LANES})")
            }
            TraceError::TrailingBytes(n) => {
                write!(f, "{n} trailing bytes after the last record")
            }
            TraceError::Io(e) => write!(f, "trace io: {e}"),
        }
    }
}

impl std::error::Error for TraceError {}

/// Outcome of one request, as recorded or as observed by a replay.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Verdict {
    /// Not recorded (live recorders cannot see the future).
    Unknown = 0,
    Ok = 1,
    DeadlineExceeded = 2,
    Cancelled = 3,
    /// Any other dispatch/execution error.
    Error = 4,
}

impl Verdict {
    pub const fn code(self) -> u8 {
        self as u8
    }

    pub fn from_code(c: u8) -> Result<Verdict, TraceError> {
        match c {
            0 => Ok(Verdict::Unknown),
            1 => Ok(Verdict::Ok),
            2 => Ok(Verdict::DeadlineExceeded),
            3 => Ok(Verdict::Cancelled),
            4 => Ok(Verdict::Error),
            _ => Err(TraceError::BadVerdict(c)),
        }
    }
}

/// How a record carries its input planes.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Content fingerprint of the original planes
    /// ([`crate::backend::fingerprint`]): replay-vs-replay
    /// deterministic (the fingerprint seeds the workload generator),
    /// but not bit-comparable to the original session.
    Fingerprint(u64),
    /// The exact input planes: replays reproduce the recorded session
    /// bit for bit, at `n_in × lanes × 4` bytes per record.
    Inline(Vec<Vec<f32>>),
    /// A [`workload::planes_for`] seed: compact and fully
    /// deterministic — the shape golden traces use.
    Seeded(u64),
}

impl Payload {
    const KIND_FINGERPRINT: u8 = 0;
    const KIND_INLINE: u8 = 1;
    const KIND_SEEDED: u8 = 2;

    fn kind(&self) -> u8 {
        match self {
            Payload::Fingerprint(_) => Self::KIND_FINGERPRINT,
            Payload::Inline(_) => Self::KIND_INLINE,
            Payload::Seeded(_) => Self::KIND_SEEDED,
        }
    }
}

/// One recorded dispatch.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    pub op: Op,
    /// Traffic class ([`CLASS_UNSPECIFIED`]..[`CLASS_BULK`]).
    pub class: u8,
    /// Tenant name (≤ 255 bytes; longer names are truncated at a char
    /// boundary when recorded).
    pub tenant: String,
    /// Arrival offset from the start of the session, nanoseconds.
    pub arrival_ns: u64,
    /// Deadline from dispatch, nanoseconds; [`NS_NONE`] = none.
    pub deadline_ns: u64,
    /// Cancel offset after dispatch, nanoseconds; [`NS_NONE`] = never.
    pub cancel_ns: u64,
    /// Recorded outcome ([`Verdict::Unknown`] for live captures).
    pub verdict: Verdict,
    /// Elements per plane.
    pub lanes: u32,
    pub payload: Payload,
}

impl TraceRecord {
    /// A seeded record: `lanes` lanes of `op` drawn by
    /// [`workload::planes_for`] from `seed`.
    pub fn seeded(op: Op, lanes: u32, seed: u64) -> TraceRecord {
        TraceRecord {
            op,
            class: CLASS_UNSPECIFIED,
            tenant: String::new(),
            arrival_ns: 0,
            deadline_ns: NS_NONE,
            cancel_ns: NS_NONE,
            verdict: Verdict::Unknown,
            lanes,
            payload: Payload::Seeded(seed),
        }
    }

    /// An inline record carrying the exact planes.
    pub fn inline(op: Op, planes: Vec<Vec<f32>>) -> TraceRecord {
        let lanes = planes.first().map_or(0, |p| p.len()) as u32;
        TraceRecord { lanes, payload: Payload::Inline(planes), ..TraceRecord::seeded(op, 0, 0) }
    }

    /// Set the arrival offset (builder-style).
    pub fn at(mut self, arrival_ns: u64) -> TraceRecord {
        self.arrival_ns = arrival_ns;
        self
    }

    pub fn tenant(mut self, tenant: &str) -> TraceRecord {
        self.tenant = tenant.to_string();
        self
    }

    pub fn class(mut self, class: u8) -> TraceRecord {
        self.class = class;
        self
    }

    pub fn deadline_ns(mut self, ns: u64) -> TraceRecord {
        self.deadline_ns = ns;
        self
    }

    pub fn cancel_ns(mut self, ns: u64) -> TraceRecord {
        self.cancel_ns = ns;
        self
    }

    pub fn verdict(mut self, v: Verdict) -> TraceRecord {
        self.verdict = v;
        self
    }

    /// Materialise this record's input planes for a replay: inline
    /// payloads clone their bits; seeded and fingerprint payloads run
    /// the deterministic workload generator (the fingerprint doubles
    /// as the seed — replay-vs-replay stable, not original-comparable).
    pub fn planes(&self) -> Vec<Vec<f32>> {
        match &self.payload {
            Payload::Inline(p) => p.clone(),
            Payload::Seeded(s) => {
                workload::planes_for(self.op.name(), self.lanes as usize, *s)
            }
            Payload::Fingerprint(fp) => {
                workload::planes_for(self.op.name(), self.lanes as usize, *fp)
            }
        }
    }

    /// Deadline as a `Duration`, when armed.
    pub fn deadline(&self) -> Option<Duration> {
        (self.deadline_ns != NS_NONE).then(|| Duration::from_nanos(self.deadline_ns))
    }

    /// Cancel offset as a `Duration`, when the request was abandoned.
    pub fn cancel_after(&self) -> Option<Duration> {
        (self.cancel_ns != NS_NONE).then(|| Duration::from_nanos(self.cancel_ns))
    }

    /// Exact encoded size in bytes (the recorder budgets against this).
    pub fn encoded_len(&self) -> usize {
        // op + class + verdict + kind + tenant_len
        let mut n = 5 + self.tenant.len();
        // arrival + deadline + cancel
        n += 8 * 3;
        // lanes
        n += 4;
        n += match &self.payload {
            Payload::Fingerprint(_) | Payload::Seeded(_) => 8,
            Payload::Inline(p) => 1 + p.iter().map(|v| v.len() * 4).sum::<usize>(),
        };
        n
    }

    fn encode_into(&self, out: &mut Vec<u8>) {
        debug_assert!(self.tenant.len() <= u8::MAX as usize);
        out.push(self.op.index() as u8);
        out.push(self.class);
        out.push(self.verdict.code());
        out.push(self.payload.kind());
        out.push(self.tenant.len() as u8);
        out.extend_from_slice(self.tenant.as_bytes());
        out.extend_from_slice(&self.arrival_ns.to_le_bytes());
        out.extend_from_slice(&self.deadline_ns.to_le_bytes());
        out.extend_from_slice(&self.cancel_ns.to_le_bytes());
        out.extend_from_slice(&self.lanes.to_le_bytes());
        match &self.payload {
            Payload::Fingerprint(x) | Payload::Seeded(x) => {
                out.extend_from_slice(&x.to_le_bytes());
            }
            Payload::Inline(planes) => {
                out.push(planes.len() as u8);
                for p in planes {
                    for v in p {
                        out.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                }
            }
        }
    }
}

/// A cursor over raw trace bytes with typed truncation failures.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], TraceError> {
        if self.buf.len() - self.pos < n {
            return Err(TraceError::Truncated(what));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, TraceError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, TraceError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, TraceError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, TraceError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

/// A recorded session: an ordered list of [`TraceRecord`]s.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    pub fn new(records: Vec<TraceRecord>) -> Trace {
        Trace { records }
    }

    /// Whether every record carries inline planes (so a replay can
    /// reproduce the recorded session bit for bit).
    pub fn all_inline(&self) -> bool {
        !self.records.is_empty()
            && self.records.iter().all(|r| matches!(r.payload, Payload::Inline(_)))
    }

    /// Canonical binary encoding (`FFTR` v1, little-endian). The flags
    /// word is derived from the records, so equal traces encode to
    /// equal bytes and decode∘encode is the identity.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            12 + self.records.iter().map(TraceRecord::encoded_len).sum::<usize>(),
        );
        out.extend_from_slice(&TRACE_MAGIC);
        out.extend_from_slice(&TRACE_VERSION.to_le_bytes());
        let flags = if self.all_inline() { FLAG_ALL_INLINE } else { 0 };
        out.extend_from_slice(&flags.to_le_bytes());
        out.extend_from_slice(&(self.records.len() as u32).to_le_bytes());
        for r in &self.records {
            r.encode_into(&mut out);
        }
        out
    }

    /// Decode a trace; total — every malformation is a typed
    /// [`TraceError`], never a panic, and no allocation happens before
    /// the byte counts backing it are validated.
    pub fn decode(bytes: &[u8]) -> Result<Trace, TraceError> {
        let mut c = Cursor { buf: bytes, pos: 0 };
        if c.take(4, "magic")? != TRACE_MAGIC {
            return Err(TraceError::BadMagic);
        }
        let version = c.u16("version")?;
        if version != TRACE_VERSION {
            return Err(TraceError::BadVersion(version));
        }
        let flags = c.u16("flags")?;
        if flags & !FLAG_ALL_INLINE != 0 {
            return Err(TraceError::BadFlags(flags));
        }
        let count = c.u32("count")? as usize;
        let mut records = Vec::new();
        for _ in 0..count {
            let op_code = c.u8("op")?;
            let op = *Op::ALL.get(op_code as usize).ok_or(TraceError::BadOp(op_code))?;
            let class = c.u8("class")?;
            if class > CLASS_MAX {
                return Err(TraceError::BadClass(class));
            }
            let verdict = Verdict::from_code(c.u8("verdict")?)?;
            let kind = c.u8("payload kind")?;
            let tenant_len = c.u8("tenant length")? as usize;
            let tenant = std::str::from_utf8(c.take(tenant_len, "tenant")?)
                .map_err(|_| TraceError::BadTenant)?
                .to_string();
            let arrival_ns = c.u64("arrival")?;
            let deadline_ns = c.u64("deadline")?;
            let cancel_ns = c.u64("cancel")?;
            let lanes = c.u32("lanes")?;
            if lanes == 0 {
                return Err(TraceError::ZeroLanes);
            }
            if lanes > MAX_LANES {
                return Err(TraceError::TooLarge { lanes });
            }
            let payload = match kind {
                Payload::KIND_FINGERPRINT => Payload::Fingerprint(c.u64("fingerprint")?),
                Payload::KIND_SEEDED => Payload::Seeded(c.u64("seed")?),
                Payload::KIND_INLINE => {
                    let n_planes = c.u8("plane count")?;
                    if n_planes as usize != op.n_in() {
                        return Err(TraceError::ArityMismatch { op, got: n_planes });
                    }
                    // length check before the alloc: a corrupt lanes
                    // field must fail typed, not OOM
                    let mut planes = Vec::with_capacity(n_planes as usize);
                    for _ in 0..n_planes {
                        let raw = c.take(lanes as usize * 4, "inline plane")?;
                        let mut p = Vec::with_capacity(lanes as usize);
                        for w in raw.chunks_exact(4) {
                            p.push(f32::from_bits(u32::from_le_bytes(
                                w.try_into().unwrap(),
                            )));
                        }
                        planes.push(p);
                    }
                    Payload::Inline(planes)
                }
                other => return Err(TraceError::BadPayloadKind(other)),
            };
            records.push(TraceRecord {
                op,
                class,
                tenant,
                arrival_ns,
                deadline_ns,
                cancel_ns,
                verdict,
                lanes,
                payload,
            });
        }
        if c.pos != bytes.len() {
            return Err(TraceError::TrailingBytes(bytes.len() - c.pos));
        }
        let trace = Trace { records };
        // canonicality: the flags must say what the records say
        let want = if trace.all_inline() { FLAG_ALL_INLINE } else { 0 };
        if flags != want {
            return Err(TraceError::BadFlags(flags));
        }
        Ok(trace)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<(), TraceError> {
        std::fs::write(path, self.encode()).map_err(|e| TraceError::Io(e.to_string()))
    }

    pub fn load(path: &std::path::Path) -> Result<Trace, TraceError> {
        let bytes =
            std::fs::read(path).map_err(|e| TraceError::Io(e.to_string()))?;
        Trace::decode(&bytes)
    }

    /// Per-op request counts, catalogue order (ops absent from the
    /// trace are omitted).
    pub fn op_counts(&self) -> Vec<(Op, u64)> {
        let mut counts = [0u64; Op::COUNT];
        for r in &self.records {
            counts[r.op.index()] += 1;
        }
        Op::ALL
            .iter()
            .filter(|o| counts[o.index()] > 0)
            .map(|o| (*o, counts[o.index()]))
            .collect()
    }
}

/// Streaming FNV-1a checksum over reply planes — the exact fold
/// `serve_demo`'s results banner prints and the CI NUMA-diff job
/// greps, now shared with the replay verifier and the replay gate.
/// Order-sensitive: callers fold replies in a deterministic order.
#[derive(Clone, Debug)]
pub struct ResultChecksum {
    fnv: u64,
}

impl Default for ResultChecksum {
    fn default() -> Self {
        ResultChecksum::new()
    }
}

impl ResultChecksum {
    pub fn new() -> ResultChecksum {
        ResultChecksum { fnv: FNV_OFFSET }
    }

    /// Fold one reply's output planes, plane-major, lane order.
    pub fn update(&mut self, planes: &[Vec<f32>]) {
        for p in planes {
            for v in p {
                self.fnv ^= v.to_bits() as u64;
                self.fnv = self.fnv.wrapping_mul(FNV_PRIME);
            }
        }
    }

    /// Fold one raw 64-bit word (verdict codes, sub-checksums).
    pub fn update_word(&mut self, word: u64) {
        self.fnv ^= word;
        self.fnv = self.fnv.wrapping_mul(FNV_PRIME);
    }

    pub fn value(&self) -> u64 {
        self.fnv
    }
}

/// Live traffic recorder, armed on
/// [`ServiceSpec::recorder`](super::service::ServiceSpec::recorder)
/// (`ServiceSpec::with_recorder`). Thread-safe; cloned `Arc`s share
/// one buffer. Drop-not-block: see the module docs.
#[derive(Debug)]
pub struct TraceRecorder {
    budget: usize,
    inline: bool,
    inner: Mutex<RecorderInner>,
    degraded: AtomicU64,
    dropped: AtomicU64,
}

#[derive(Debug)]
struct RecorderInner {
    started: Instant,
    records: Vec<TraceRecord>,
    bytes: usize,
    classes: BTreeMap<String, u8>,
}

impl TraceRecorder {
    /// A recorder with a `budget_bytes` cap on the encoded trace.
    /// `inline` records full plane bits (bit-exact replays, large
    /// traces); otherwise each record carries a content fingerprint.
    pub fn new(budget_bytes: usize, inline: bool) -> TraceRecorder {
        TraceRecorder {
            budget: budget_bytes,
            inline,
            inner: Mutex::new(RecorderInner {
                started: Instant::now(),
                records: Vec::new(),
                bytes: 12, // header
                classes: BTreeMap::new(),
            }),
            degraded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Note `tenant`'s traffic class (the wire front end calls this at
    /// `ClientHello`); subsequent records for that tenant carry it.
    pub fn note_class(&self, tenant: &str, class: u8) {
        let mut g = self.inner.lock().unwrap();
        g.classes.insert(tenant.to_string(), class.min(CLASS_MAX));
    }

    /// Record one dispatch. Called by the coordinator at the dispatch
    /// boundary — before cache, sampler and routing — so the capture
    /// is complete and invisible. Never blocks on the budget: an
    /// over-budget inline record degrades to fingerprint-only; a
    /// record that still does not fit is dropped and counted.
    pub fn log(
        &self, op: Op, planes: &[Vec<f32>], tenant: &str, deadline: Option<Duration>,
    ) {
        let mut g = self.inner.lock().unwrap();
        let arrival_ns =
            u64::try_from(g.started.elapsed().as_nanos()).unwrap_or(u64::MAX - 1);
        let lanes = planes.first().map_or(0, |p| p.len()) as u32;
        if lanes == 0 || lanes > MAX_LANES {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut tenant = tenant;
        if tenant.len() > u8::MAX as usize {
            // truncate at a char boundary; recording must not fail
            let mut cut = u8::MAX as usize;
            while !tenant.is_char_boundary(cut) {
                cut -= 1;
            }
            tenant = &tenant[..cut];
        }
        let class = g.classes.get(tenant).copied().unwrap_or(CLASS_UNSPECIFIED);
        let base = TraceRecord {
            op,
            class,
            tenant: tenant.to_string(),
            arrival_ns,
            deadline_ns: deadline
                .map(|d| u64::try_from(d.as_nanos()).unwrap_or(NS_NONE - 1))
                .unwrap_or(NS_NONE),
            cancel_ns: NS_NONE,
            verdict: Verdict::Unknown,
            lanes,
            payload: Payload::Fingerprint(0),
        };
        let mut rec = if self.inline {
            TraceRecord { payload: Payload::Inline(planes.to_vec()), ..base.clone() }
        } else {
            TraceRecord { payload: Payload::Fingerprint(fingerprint(op, planes)), ..base.clone() }
        };
        if g.bytes + rec.encoded_len() > self.budget {
            if matches!(rec.payload, Payload::Inline(_)) {
                // degrade, then re-check the fingerprint-sized record
                rec = TraceRecord {
                    payload: Payload::Fingerprint(fingerprint(op, planes)),
                    ..base
                };
                if g.bytes + rec.encoded_len() > self.budget {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                self.degraded.fetch_add(1, Ordering::Relaxed);
            } else {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        g.bytes += rec.encoded_len();
        g.records.push(rec);
    }

    /// Snapshot the recorded session.
    pub fn trace(&self) -> Trace {
        Trace { records: self.inner.lock().unwrap().records.clone() }
    }

    /// Records captured so far.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Encoded bytes the captured trace will occupy.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Records whose inline planes were degraded to fingerprints by
    /// the byte budget.
    pub fn degraded(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Records discarded outright by the byte budget.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Per-op replay outcome row.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpReplayRow {
    pub op: &'static str,
    pub requests: u64,
    pub ok: u64,
    pub deadline_exceeded: u64,
    pub cancelled: u64,
    pub errors: u64,
    /// Useful lanes across this op's requests.
    pub lanes: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// What one [`replay`] measured.
#[derive(Clone, Debug, Default)]
pub struct ReplayReport {
    /// Speed multiplier the arrival gaps were replayed at.
    pub rate: f64,
    /// Records dispatched.
    pub records: usize,
    /// Wall-clock seconds the replay took.
    pub wall_s: f64,
    /// The trace's virtual span (last arrival offset), seconds.
    pub virtual_s: f64,
    /// Per-op rows, catalogue order (ops absent from the trace omitted).
    pub per_op: Vec<OpReplayRow>,
    /// Padding-waste fraction over the lanes this replay launched
    /// (service-delta, so a shared service only counts this replay).
    pub padding_waste: f64,
    /// Cache hit rate over this replay's lookups (0 when no cache).
    pub cache_hit_rate: f64,
    /// Tenant-ledger shed/denial deltas (nonzero only when a front end
    /// in front of the service rejects during the replay).
    pub shed: u64,
    pub denied: u64,
    /// FNV fold of (verdict code, per-reply checksum) in record order
    /// — identical run over run and config over config.
    pub results_fnv: u64,
    /// Whether every record carried inline planes (the checksum is
    /// then also comparable to the recorded session's banner).
    pub all_inline: bool,
}

impl ReplayReport {
    /// One value pinning everything determinism guarantees: the
    /// results checksum plus every per-op request/verdict/lane count.
    /// Two replays of one trace on one config must agree on this.
    pub fn determinism_key(&self) -> u64 {
        let mut c = ResultChecksum::new();
        c.update_word(self.results_fnv);
        for row in &self.per_op {
            for w in [
                row.requests,
                row.ok,
                row.deadline_exceeded,
                row.cancelled,
                row.errors,
                row.lanes,
            ] {
                c.update_word(w);
            }
        }
        c.value()
    }

    /// Human-readable multi-line summary (the demo and gate print it).
    pub fn render(&self) -> String {
        let mut s = format!(
            "replay: {} records at {}x, wall {:.3}s (virtual {:.3}s)\n",
            self.records, self.rate, self.wall_s, self.virtual_s
        );
        for r in &self.per_op {
            s.push_str(&format!(
                "  {:<6} req={:<4} ok={:<4} dl={:<3} cancel={:<3} err={:<3} \
                 lanes={:<8} p50={:.3}ms p95={:.3}ms\n",
                r.op,
                r.requests,
                r.ok,
                r.deadline_exceeded,
                r.cancelled,
                r.errors,
                r.lanes,
                r.p50_ms,
                r.p95_ms
            ));
        }
        s.push_str(&format!(
            "  padding waste {:.4}  cache hit rate {:.4}  shed {}  denied {}\n",
            self.padding_waste, self.cache_hit_rate, self.shed, self.denied
        ));
        s.push_str(&format!(
            "  results checksum: {:#018x}  (inline: {})\n",
            self.results_fnv, self.all_inline
        ));
        s
    }
}

/// In-flight cap during a replay: beyond this many outstanding
/// tickets the scheduler joins the oldest waiter before dispatching
/// more (bounds thread count on huge traces).
const REPLAY_MAX_IN_FLIGHT: usize = 512;

struct Outcome {
    verdict: Verdict,
    latency_s: f64,
    fnv: u64,
}

/// Replay `trace` against `svc` at `rate`× recorded speed.
///
/// Virtual-clock pacing: record `i` dispatches once
/// `arrival_ns[i] / rate` of wall clock has elapsed since the replay
/// started (a slow service pushes the clock late; gaps never stretch).
/// Deadlines and cancel offsets apply **unscaled** so verdicts are
/// speed-robust (see the module docs). Tenants are re-attributed
/// through [`super::Handle::dispatch_tagged_deadline`], so the
/// replayed service's tenant ledger sees the recorded traffic mix.
///
/// Determinism: replaying one trace twice on one configuration yields
/// identical [`ReplayReport::results_fnv`] and identical per-op
/// request/verdict counts ([`ReplayReport::determinism_key`]) — and
/// because the serving stack's routing/fusion/cache layers are
/// bit-transparent, the same holds *across* configurations.
pub fn replay(svc: &Service, trace: &Trace, rate: f64) -> Result<ReplayReport, ServiceError> {
    if !rate.is_finite() || rate <= 0.0 {
        return Err(ServiceError::Backend(format!("bad replay rate {rate}")));
    }
    let h = svc.handle();
    let before = svc.metrics();
    let cache_before = svc.cache_stats();
    let tenants_before = svc.tenant_metrics();

    let n = trace.records.len();
    let mut outcomes: Vec<Option<Outcome>> = Vec::with_capacity(n);
    outcomes.resize_with(n, || None);
    type Waiters = std::collections::VecDeque<std::thread::JoinHandle<(usize, Outcome)>>;
    let mut waiters: Waiters = Waiters::new();
    fn join_one(waiters: &mut Waiters, outcomes: &mut [Option<Outcome>]) {
        if let Some(jh) = waiters.pop_front() {
            if let Ok((idx, out)) = jh.join() {
                outcomes[idx] = Some(out);
            }
        }
    }

    let started = Instant::now();
    for (idx, rec) in trace.records.iter().enumerate() {
        // virtual clock: the recorded arrival offset, scaled by 1/rate
        let target = Duration::from_nanos((rec.arrival_ns as f64 / rate) as u64);
        let now = started.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let plan = Plan::new(rec.op, rec.planes())?;
        let ticket = h.dispatch_tagged_deadline(&rec.tenant, plan, rec.deadline())?;
        let cancel_after = rec.cancel_after();
        let dispatched = Instant::now();
        let jh = std::thread::spawn(move || {
            if let Some(d) = cancel_after {
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
                ticket.cancel();
            }
            let result = ticket.wait();
            let latency_s = dispatched.elapsed().as_secs_f64();
            let (verdict, fnv) = match result {
                Ok(planes) => {
                    let mut c = ResultChecksum::new();
                    c.update(&planes);
                    (Verdict::Ok, c.value())
                }
                Err(ServiceError::DeadlineExceeded) => (Verdict::DeadlineExceeded, 0),
                Err(ServiceError::Cancelled) => (Verdict::Cancelled, 0),
                Err(_) => (Verdict::Error, 0),
            };
            (idx, Outcome { verdict, latency_s, fnv })
        });
        waiters.push_back(jh);
        while waiters.len() > REPLAY_MAX_IN_FLIGHT {
            join_one(&mut waiters, &mut outcomes);
        }
    }
    while !waiters.is_empty() {
        join_one(&mut waiters, &mut outcomes);
    }
    let wall_s = started.elapsed().as_secs_f64();

    // fold outcomes in record order: completion order cannot leak in
    let mut results = ResultChecksum::new();
    let mut rows: Vec<OpReplayRow> = Vec::new();
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); Op::COUNT];
    let mut row_ix = [usize::MAX; Op::COUNT];
    for (rec, out) in trace.records.iter().zip(&outcomes) {
        // a waiter that died (joined Err) counts as an error verdict
        let verdict = out.as_ref().map_or(Verdict::Error, |o| o.verdict);
        let fnv = out.as_ref().map_or(0, |o| o.fnv);
        let latency_s = out.as_ref().map_or(0.0, |o| o.latency_s);
        results.update_word(verdict.code() as u64);
        results.update_word(fnv);
        let k = rec.op.index();
        if row_ix[k] == usize::MAX {
            row_ix[k] = rows.len();
            rows.push(OpReplayRow { op: rec.op.name(), ..OpReplayRow::default() });
        }
        let row = &mut rows[row_ix[k]];
        row.requests += 1;
        row.lanes += rec.lanes as u64;
        match verdict {
            Verdict::Ok => row.ok += 1,
            Verdict::DeadlineExceeded => row.deadline_exceeded += 1,
            Verdict::Cancelled => row.cancelled += 1,
            _ => row.errors += 1,
        }
        latencies[k].push(latency_s);
    }
    // catalogue order, independent of arrival order
    rows.sort_by_key(|r| Op::parse(r.op).map(Op::index).unwrap_or(usize::MAX));
    for row in &mut rows {
        let k = Op::parse(row.op).expect("row op is canonical").index();
        let lat = &mut latencies[k];
        lat.sort_by(|a, b| a.total_cmp(b));
        row.p50_ms = percentile(lat, 50.0) * 1e3;
        row.p95_ms = percentile(lat, 95.0) * 1e3;
    }

    let after = svc.metrics();
    let d_useful = after.elements.saturating_sub(before.elements);
    let d_padded = after.padded_elements.saturating_sub(before.padded_elements);
    let padding_waste = if d_useful + d_padded == 0 {
        0.0
    } else {
        d_padded as f64 / (d_useful + d_padded) as f64
    };
    let cache_hit_rate = match (cache_before, svc.cache_stats()) {
        (Some(b), Some(a)) => {
            let hits = a.hits.saturating_sub(b.hits);
            let misses = a.misses.saturating_sub(b.misses);
            if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 }
        }
        _ => 0.0,
    };
    let tenants_after = svc.tenant_metrics();
    let sum = |m: &BTreeMap<String, super::metrics::TenantCounters>| {
        m.values().fold((0u64, 0u64), |(s, d), c| (s + c.shed, d + c.denied))
    };
    let (shed_b, denied_b) = sum(&tenants_before);
    let (shed_a, denied_a) = sum(&tenants_after);

    Ok(ReplayReport {
        rate,
        records: n,
        wall_s,
        virtual_s: trace.records.last().map_or(0.0, |r| r.arrival_ns as f64 / 1e9),
        per_op: rows,
        padding_waste,
        cache_hit_rate,
        shed: shed_a.saturating_sub(shed_b),
        denied: denied_a.saturating_sub(denied_b),
        results_fnv: results.value(),
        all_inline: trace.all_inline(),
    })
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0 * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        Trace::new(vec![
            TraceRecord::seeded(Op::Add22, 64, 7).tenant("alpha").at(0),
            TraceRecord::seeded(Op::Mul22, 33, 9)
                .tenant("beta")
                .class(CLASS_INTERACTIVE)
                .at(1_000)
                .deadline_ns(5_000_000_000)
                .verdict(Verdict::Ok),
            TraceRecord::inline(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]])
                .at(2_000)
                .cancel_ns(0)
                .verdict(Verdict::Cancelled),
        ])
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let t = sample_trace();
        let bytes = t.encode();
        let back = Trace::decode(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.encode(), bytes);
        // mixed payloads: not all inline
        assert!(!t.all_inline());
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
    }

    #[test]
    fn all_inline_flag_derives_from_records() {
        let t = Trace::new(vec![TraceRecord::inline(
            Op::Add,
            vec![vec![1.0], vec![2.0]],
        )]);
        assert!(t.all_inline());
        let bytes = t.encode();
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), FLAG_ALL_INLINE);
        assert_eq!(Trace::decode(&bytes).unwrap(), t);
        // empty traces are not "all inline"
        assert!(!Trace::default().all_inline());
    }

    #[test]
    fn truncation_fails_typed_everywhere() {
        let bytes = sample_trace().encode();
        for cut in 0..bytes.len() {
            let err = Trace::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, TraceError::Truncated(_)),
                "cut at {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn recorder_budget_degrades_then_drops() {
        // header (12) + one inline add record (67) + one fingerprint
        // record (42) = 121 bytes; 140 holds exactly that and no more
        let rec = TraceRecorder::new(140, true);
        let planes = vec![vec![1.0f32; 4], vec![2.0f32; 4]];
        rec.log(Op::Add, &planes, "t", None); // inline fits: 12+67=79
        assert_eq!((rec.len(), rec.degraded(), rec.dropped()), (1, 0, 0));
        rec.log(Op::Add, &planes, "t", None); // inline would burst: degrade
        assert_eq!((rec.len(), rec.degraded(), rec.dropped()), (2, 1, 0));
        rec.log(Op::Add, &planes, "t", None); // even a fingerprint bursts: drop
        assert_eq!((rec.len(), rec.degraded(), rec.dropped()), (2, 1, 1));
        let t = rec.trace();
        assert!(matches!(t.records[0].payload, Payload::Inline(_)));
        assert!(matches!(t.records[1].payload, Payload::Fingerprint(_)));
        assert!(t.encode().len() <= 140);
    }

    #[test]
    fn recorder_tracks_class_and_deadline() {
        let rec = TraceRecorder::new(1 << 20, false);
        rec.note_class("alpha", CLASS_INTERACTIVE);
        let planes = vec![vec![1.0f32; 2], vec![2.0f32; 2]];
        rec.log(Op::Add, &planes, "alpha", Some(Duration::from_millis(3)));
        rec.log(Op::Add, &planes, "beta", None);
        let t = rec.trace();
        assert_eq!(t.records[0].class, CLASS_INTERACTIVE);
        assert_eq!(t.records[0].deadline_ns, 3_000_000);
        assert_eq!(t.records[1].class, CLASS_UNSPECIFIED);
        assert_eq!(t.records[1].deadline_ns, NS_NONE);
        assert!(t.records[1].arrival_ns >= t.records[0].arrival_ns);
    }

    #[test]
    fn checksum_matches_manual_fnv_fold() {
        let planes = vec![vec![1.5f32, -2.25], vec![0.0f32, 3.0]];
        let mut c = ResultChecksum::new();
        c.update(&planes);
        let mut want = FNV_OFFSET;
        for p in &planes {
            for v in p {
                want ^= v.to_bits() as u64;
                want = want.wrapping_mul(FNV_PRIME);
            }
        }
        assert_eq!(c.value(), want);
        assert_ne!(c.value(), ResultChecksum::new().value());
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 95.0), 4.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn seeded_planes_are_deterministic_and_shaped() {
        let r = TraceRecord::seeded(Op::Mul22, 100, 42);
        let a = r.planes();
        let b = r.planes();
        assert_eq!(a, b);
        assert_eq!(a.len(), Op::Mul22.n_in());
        assert!(a.iter().all(|p| p.len() == 100));
    }
}
