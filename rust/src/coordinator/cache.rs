//! Content-addressed result cache with single-flight dedup.
//!
//! Every catalogue operator is a pure, deterministic function of its
//! input planes, so a request's result is fully determined by its
//! [`crate::backend::fingerprint`] key.
//! [`Handle::dispatch`](crate::coordinator::service::Handle::dispatch)
//! consults a [`ResultCache`] *before* routing:
//!
//! - **Hit** — the output planes are already resident: the reply is
//!   pre-sent into the ticket's channel and no shard (and no routing
//!   policy, and no observatory sampler) ever sees the request.
//! - **Follow** — an identical request is in flight: the caller's
//!   reply sender attaches to the leader's entry and the ticket
//!   resolves when the leader's shard replies. One execution serves
//!   all concurrent identical dispatches (single-flight).
//! - **Lead** — first sighting: the dispatch proceeds normally,
//!   carrying a [`CacheFill`] obligation in its
//!   [`OpRequest`](crate::coordinator::request::OpRequest). The shard
//!   resolves it exactly once — success inserts the result and fans it
//!   out to followers, failure fans out the error — and if the request
//!   is dropped unresolved (service shutdown), `CacheFill::drop` fails
//!   the followers rather than leaving them blocked forever.
//!
//! **Leader lifecycle vs. followers.** A leader that is cancelled or
//! deadline-expired at shard triage must not doom its followers — their
//! tickets carry their *own* deadlines. The shard promotes a live
//! follower into the leadership slot ([`ResultCache::pop_follower`])
//! and executes for it. Genuine *execution* errors (backend failures)
//! are shared with followers: they are the computation's outcome, not
//! an artifact of the leader's client.
//!
//! **Memory bound.** The cache is split into [`CACHE_SHARDS`] lock
//! stripes by the key's top bits; each stripe owns an equal slice of
//! the byte budget and evicts with a cost-aware **segmented LRU**: new
//! entries enter *probation*, a re-hit promotes to *protected* (capped
//! at 3/4 of the stripe so scans cannot flush the working set), and
//! eviction takes the least recently used probation entry — except
//! when the second-oldest is cheaper to recompute per byte retained
//! (measured execution seconds / entry bytes), in which case the
//! cheap-dense one goes first.
//!
//! **Invisibility.** Hits and coalesced follows never call the routing
//! policy, never touch [`ShardMeta`](crate::coordinator::routing::ShardMeta)
//! queue depths or rate EWMAs, and never tick the observatory sampler
//! — cache activity is accounted only in its own [`CacheTelemetry`]
//! cells. See `cache_hits_do_not_perturb_routing_or_observatory` in
//! the integration suite.

use super::metrics::{CacheOpStats, CacheTelemetry};
use super::plan::TicketState;
use super::request::OpResult;
use crate::backend::{Op, ServiceError};
use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Mutex};

/// Lock stripes. Keyed by the fingerprint's top 16 bits so stripe
/// choice is independent of the HashMap's own bucket choice (low bits).
pub const CACHE_SHARDS: usize = 16;

/// Charged per cached entry beyond its lane payload (map slot, queues,
/// bookkeeping), so a flood of tiny results still respects the budget.
const ENTRY_OVERHEAD: usize = 64;

/// Charged per output plane (Vec header + allocator slop).
const PLANE_OVERHEAD: usize = 32;

/// Fraction of a stripe's budget the protected segment may hold: 3/4.
/// The remainder guarantees probation always has room to admit new
/// entries, so one-shot scans recycle through probation without
/// evicting the proven working set.
const PROTECTED_NUM: usize = 3;
const PROTECTED_DEN: usize = 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Segment {
    Probation,
    Protected,
}

/// One resident result.
#[derive(Debug)]
struct Entry {
    op: Op,
    planes: Arc<Vec<Vec<f32>>>,
    bytes: usize,
    /// Measured seconds the leader's execution took — the recompute
    /// cost this entry saves, read by cost-aware eviction.
    cost_s: f64,
    /// Shard that produced the result; hit tickets report it so
    /// attribution stays meaningful.
    shard: usize,
    segment: Segment,
}

/// One in-flight computation; followers' reply senders park here until
/// the leader resolves.
struct Inflight {
    shard: usize,
    followers: Vec<(mpsc::Sender<OpResult>, Arc<TicketState>)>,
}

#[derive(Default)]
struct Stripe {
    entries: HashMap<u64, Entry>,
    /// LRU order within each segment: front = oldest.
    probation: VecDeque<u64>,
    protected: VecDeque<u64>,
    bytes: usize,
    protected_bytes: usize,
    inflight: HashMap<u64, Inflight>,
}

/// What [`ResultCache::begin`] decided for one dispatch.
#[derive(Debug)]
pub(crate) enum Decision {
    /// Resident: reply with these planes immediately; `shard` produced
    /// them originally (ticket attribution only).
    Hit { planes: Arc<Vec<Vec<f32>>>, shard: usize },
    /// Coalesced onto an in-flight leader; the caller's sender is
    /// attached and will receive the leader's outcome.
    Follow { shard: usize },
    /// First sighting: caller must dispatch and carry a [`CacheFill`].
    Lead,
}

/// Aggregate cache counters — the shape that rides the wire Status
/// frame and the serve_demo banner.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Single-flight followers (identical dispatches that attached to
    /// a leader instead of executing).
    pub coalesced: u64,
    pub inserted_bytes: u64,
    pub evictions: u64,
    pub live_bytes: u64,
    pub budget_bytes: u64,
}

impl CacheStats {
    /// Hit fraction over all lookups (hits + coalesced count as saved
    /// executions; misses as paid ones). 0.0 when cold.
    pub fn hit_rate(&self) -> f64 {
        let saved = self.hits + self.coalesced;
        let total = saved + self.misses;
        if total == 0 { 0.0 } else { saved as f64 / total as f64 }
    }
}

/// The sharded, content-addressed result cache (see module docs).
pub struct ResultCache {
    stripes: Vec<Mutex<Stripe>>,
    stripe_budget: usize,
    telemetry: CacheTelemetry,
}

impl std::fmt::Debug for ResultCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultCache")
            .field("stripes", &self.stripes.len())
            .field("stripe_budget", &self.stripe_budget)
            .finish_non_exhaustive()
    }
}

fn entry_bytes(planes: &[Vec<f32>]) -> usize {
    ENTRY_OVERHEAD
        + planes
            .iter()
            .map(|p| PLANE_OVERHEAD + p.len() * std::mem::size_of::<f32>())
            .sum::<usize>()
}

impl ResultCache {
    /// A cache bounded to `total_bytes`, split evenly across
    /// [`CACHE_SHARDS`] lock stripes.
    pub fn with_budget(total_bytes: usize) -> ResultCache {
        ResultCache {
            stripes: (0..CACHE_SHARDS).map(|_| Mutex::new(Stripe::default())).collect(),
            stripe_budget: (total_bytes / CACHE_SHARDS).max(ENTRY_OVERHEAD),
            telemetry: CacheTelemetry::new(),
        }
    }

    fn stripe_for(&self, key: u64) -> &Mutex<Stripe> {
        &self.stripes[(key >> 48) as usize % self.stripes.len()]
    }

    fn protected_cap(&self) -> usize {
        self.stripe_budget * PROTECTED_NUM / PROTECTED_DEN
    }

    /// Resolve one dispatch against the cache, atomically under the
    /// key's stripe lock: hit → promote + return planes; in-flight →
    /// attach `reply`/`ctrl` as a follower; otherwise register the
    /// caller as leader.
    pub(crate) fn begin(
        &self,
        op: Op,
        key: u64,
        reply: &mpsc::Sender<OpResult>,
        ctrl: &Arc<TicketState>,
    ) -> Decision {
        let mut s = self.stripe_for(key).lock().unwrap();
        if s.entries.contains_key(&key) {
            Self::promote(&mut s, key, self.protected_cap());
            let e = &s.entries[&key];
            let d = Decision::Hit { planes: e.planes.clone(), shard: e.shard };
            self.telemetry.record_hit(op);
            return d;
        }
        if let Some(f) = s.inflight.get_mut(&key) {
            f.followers.push((reply.clone(), ctrl.clone()));
            let shard = f.shard;
            self.telemetry.record_coalesced(op);
            return Decision::Follow { shard };
        }
        s.inflight.insert(key, Inflight { shard: 0, followers: Vec::new() });
        self.telemetry.record_miss(op);
        Decision::Lead
    }

    /// Record which shard the leader was routed to (followers that
    /// attach before routing completes default to shard 0; this is
    /// attribution only, never placement).
    pub(crate) fn set_origin(&self, key: u64, shard: usize) {
        let mut s = self.stripe_for(key).lock().unwrap();
        if let Some(f) = s.inflight.get_mut(&key) {
            f.shard = shard;
        }
    }

    /// Detach one parked follower (most recently attached first) —
    /// used by the shard to promote a live follower into the
    /// leadership slot when the leader's client cancelled or expired.
    pub(crate) fn pop_follower(
        &self,
        key: u64,
    ) -> Option<(mpsc::Sender<OpResult>, Arc<TicketState>)> {
        let mut s = self.stripe_for(key).lock().unwrap();
        s.inflight.get_mut(&key).and_then(|f| f.followers.pop())
    }

    /// Leader succeeded: insert the result (unless it alone exceeds a
    /// stripe's budget), evicting as needed, and return the followers'
    /// senders so the caller can fan the planes out *outside* the
    /// stripe lock.
    pub(crate) fn fill_complete(
        &self,
        op: Op,
        key: u64,
        origin: usize,
        planes: &Arc<Vec<Vec<f32>>>,
        cost_s: f64,
    ) -> Vec<mpsc::Sender<OpResult>> {
        let mut s = self.stripe_for(key).lock().unwrap();
        let followers =
            s.inflight.remove(&key).map(|f| f.followers).unwrap_or_default();
        if !s.entries.contains_key(&key) {
            let bytes = entry_bytes(planes);
            if bytes <= self.stripe_budget {
                while s.bytes + bytes > self.stripe_budget {
                    if !self.evict_one(&mut s) {
                        break;
                    }
                }
                s.bytes += bytes;
                s.probation.push_back(key);
                s.entries.insert(
                    key,
                    Entry {
                        op,
                        planes: planes.clone(),
                        bytes,
                        cost_s,
                        shard: origin,
                        segment: Segment::Probation,
                    },
                );
                self.telemetry.record_insert(op, bytes as u64);
            }
        }
        drop(s);
        followers.into_iter().map(|(tx, _ctrl)| tx).collect()
    }

    /// Leader failed (or was dropped unresolved): clear the in-flight
    /// entry and share the error with every parked follower — an
    /// execution error is the computation's outcome, and a dropped
    /// leader must not leave followers blocked forever.
    pub(crate) fn fill_fail(&self, key: u64, err: &ServiceError) {
        let followers = {
            let mut s = self.stripe_for(key).lock().unwrap();
            s.inflight.remove(&key).map(|f| f.followers).unwrap_or_default()
        };
        for (tx, _ctrl) in followers {
            let _ = tx.send(Err(err.clone()));
        }
    }

    /// Evict one entry from `s`: normally the oldest probation entry,
    /// but when the two oldest differ in recompute value per byte
    /// (cost_s / bytes), the cheaper-denser one goes first. Protected
    /// entries fall only once probation is empty. Returns false when
    /// the stripe is already empty.
    fn evict_one(&self, s: &mut Stripe) -> bool {
        let victim = if s.probation.len() >= 2 {
            let (a, b) = (s.probation[0], s.probation[1]);
            let density = |k: u64| {
                let e = &s.entries[&k];
                e.cost_s / e.bytes.max(1) as f64
            };
            if density(b) < density(a) {
                s.probation.remove(1);
                b
            } else {
                s.probation.pop_front();
                a
            }
        } else if let Some(v) = s.probation.pop_front() {
            v
        } else if let Some(v) = s.protected.pop_front() {
            v
        } else {
            return false;
        };
        let e = s.entries.remove(&victim).expect("queued key has an entry");
        s.bytes -= e.bytes;
        if e.segment == Segment::Protected {
            s.protected_bytes -= e.bytes;
        }
        self.telemetry.record_eviction(e.op);
        true
    }

    /// Segmented-LRU touch on a hit: probation → protected (demoting
    /// the protected segment's oldest back to probation while it
    /// overflows its cap), protected → refresh recency.
    fn promote(s: &mut Stripe, key: u64, protected_cap: usize) {
        let (segment, bytes) = match s.entries.get(&key) {
            Some(e) => (e.segment, e.bytes),
            None => return,
        };
        match segment {
            Segment::Probation => {
                if let Some(pos) = s.probation.iter().position(|&k| k == key) {
                    s.probation.remove(pos);
                }
                s.protected.push_back(key);
                s.entries.get_mut(&key).expect("present above").segment =
                    Segment::Protected;
                s.protected_bytes += bytes;
                while s.protected_bytes > protected_cap {
                    let Some(old) = s.protected.pop_front() else { break };
                    let e = s.entries.get_mut(&old).expect("queued key has an entry");
                    e.segment = Segment::Probation;
                    s.protected_bytes -= e.bytes;
                    s.probation.push_back(old);
                }
            }
            Segment::Protected => {
                if let Some(pos) = s.protected.iter().position(|&k| k == key) {
                    s.protected.remove(pos);
                    s.protected.push_back(key);
                }
            }
        }
    }

    /// Bytes currently resident across all stripes.
    pub fn live_bytes(&self) -> usize {
        self.stripes.iter().map(|m| m.lock().unwrap().bytes).sum()
    }

    /// Configured capacity (stripe budget × stripe count; may round
    /// below the requested total by up to [`CACHE_SHARDS`]−1 bytes).
    pub fn budget_bytes(&self) -> usize {
        self.stripe_budget * self.stripes.len()
    }

    /// Per-op counters.
    pub fn op_stats(&self, op: Op) -> CacheOpStats {
        self.telemetry.op_stats(op)
    }

    /// Aggregate counters + occupancy, the wire/banner shape.
    pub fn stats(&self) -> CacheStats {
        let t = self.telemetry.totals();
        CacheStats {
            hits: t.hits,
            misses: t.misses,
            coalesced: t.coalesced,
            inserted_bytes: t.inserted_bytes,
            evictions: t.evictions,
            live_bytes: self.live_bytes() as u64,
            budget_bytes: self.budget_bytes() as u64,
        }
    }
}

/// The leader's obligation to resolve its in-flight cache entry,
/// carried inside the leader's `OpRequest`. Exactly one of
/// [`complete`](CacheFill::complete) / [`fail`](CacheFill::fail) runs
/// on the shard thread; if neither does (request dropped on shutdown),
/// `Drop` fails the entry so followers unblock.
pub(crate) struct CacheFill {
    cache: Arc<ResultCache>,
    op: Op,
    key: u64,
    shard: usize,
    done: bool,
}

impl std::fmt::Debug for CacheFill {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheFill")
            .field("op", &self.op)
            .field("key", &format_args!("{:#018x}", self.key))
            .field("shard", &self.shard)
            .field("done", &self.done)
            .finish()
    }
}

impl CacheFill {
    pub(crate) fn new(cache: Arc<ResultCache>, op: Op, key: u64) -> CacheFill {
        CacheFill { cache, op, key, shard: 0, done: false }
    }

    /// Record the routed shard (attribution for hit tickets).
    pub(crate) fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
        self.cache.set_origin(self.key, shard);
    }

    /// Detach one parked follower for leadership promotion.
    pub(crate) fn pop_follower(
        &self,
    ) -> Option<(mpsc::Sender<OpResult>, Arc<TicketState>)> {
        self.cache.pop_follower(self.key)
    }

    /// Resolve with the executed output planes: insert into the cache,
    /// fan copies out to followers, and hand the planes back for the
    /// leader's own reply (reclaimed without a copy when the cache
    /// skipped the insert, cloned outside any stripe lock otherwise).
    /// `cost_s` is the measured execution time this entry would save.
    pub(crate) fn complete(&mut self, planes: Vec<Vec<f32>>, cost_s: f64) -> Vec<Vec<f32>> {
        self.done = true;
        let shared = Arc::new(planes);
        let followers =
            self.cache.fill_complete(self.op, self.key, self.shard, &shared, cost_s);
        for tx in followers {
            let _ = tx.send(Ok(shared.as_ref().clone()));
        }
        match Arc::try_unwrap(shared) {
            Ok(planes) => planes,
            Err(shared) => shared.as_ref().clone(),
        }
    }

    /// Resolve with an execution error, shared with followers.
    pub(crate) fn fail(&mut self, err: &ServiceError) {
        self.done = true;
        self.cache.fill_fail(self.key, err);
    }
}

impl Drop for CacheFill {
    fn drop(&mut self) {
        if !self.done {
            // shutdown path: the request (and its fill) was dropped
            // without executing — same verdict a shard-less submit gets
            self.cache.fill_fail(self.key, &ServiceError::QueueClosed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn waiter() -> (mpsc::Sender<OpResult>, mpsc::Receiver<OpResult>, Arc<TicketState>) {
        let (tx, rx) = mpsc::channel();
        (tx, rx, Arc::new(TicketState::new()))
    }

    /// Keys sharing the top 16 bits land in one stripe, which makes
    /// eviction order deterministic in tests.
    fn same_stripe_key(n: u64) -> u64 {
        assert!(n < (1 << 48));
        n
    }

    fn planes_of(lanes: usize, fill: f32) -> Arc<Vec<Vec<f32>>> {
        Arc::new(vec![vec![fill; lanes], vec![fill + 1.0; lanes]])
    }

    #[test]
    fn lead_fill_hit_roundtrip_is_bit_identical() {
        let c = ResultCache::with_budget(1 << 20);
        let (tx, _rx, ctrl) = waiter();
        let key = 42;
        assert!(matches!(c.begin(Op::Add22, key, &tx, &ctrl), Decision::Lead));
        let out = Arc::new(vec![vec![1.5f32, -0.0, f32::NAN], vec![0.25, 2.0, -1.0]]);
        let followers = c.fill_complete(Op::Add22, key, 3, &out, 0.01);
        assert!(followers.is_empty());
        match c.begin(Op::Add22, key, &tx, &ctrl) {
            Decision::Hit { planes, shard } => {
                assert_eq!(shard, 3);
                let same = planes.iter().zip(out.iter()).all(|(a, b)| {
                    a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
                });
                assert!(same, "hit planes must be bit-identical (incl. NaN/-0.0)");
            }
            d => panic!("expected hit, got {d:?}"),
        }
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.coalesced), (1, 1, 0));
        assert!(s.inserted_bytes > 0);
        assert_eq!(s.live_bytes as usize, c.live_bytes());
    }

    #[test]
    fn concurrent_identical_misses_coalesce_and_fan_out() {
        let c = ResultCache::with_budget(1 << 20);
        let key = 7;
        let (lead_tx, _lead_rx, lead_ctrl) = waiter();
        assert!(matches!(c.begin(Op::Mul22, key, &lead_tx, &lead_ctrl), Decision::Lead));
        let (f1_tx, f1_rx, f1_ctrl) = waiter();
        let (f2_tx, f2_rx, f2_ctrl) = waiter();
        assert!(matches!(
            c.begin(Op::Mul22, key, &f1_tx, &f1_ctrl),
            Decision::Follow { .. }
        ));
        assert!(matches!(
            c.begin(Op::Mul22, key, &f2_tx, &f2_ctrl),
            Decision::Follow { .. }
        ));
        let out = planes_of(8, 0.5);
        let followers = c.fill_complete(Op::Mul22, key, 0, &out, 0.001);
        assert_eq!(followers.len(), 2);
        for tx in followers {
            tx.send(Ok(out.as_ref().clone())).unwrap();
        }
        assert_eq!(f1_rx.try_recv().unwrap().unwrap(), *out);
        assert_eq!(f2_rx.try_recv().unwrap().unwrap(), *out);
        let s = c.stats();
        assert_eq!((s.misses, s.coalesced, s.hits), (1, 2, 0));
    }

    #[test]
    fn failed_fill_shares_error_with_followers() {
        let c = Arc::new(ResultCache::with_budget(1 << 20));
        let key = 9;
        let (lead_tx, _lead_rx, lead_ctrl) = waiter();
        let mut fill = match c.begin(Op::Div22, key, &lead_tx, &lead_ctrl) {
            Decision::Lead => CacheFill::new(c.clone(), Op::Div22, key),
            d => panic!("expected lead, got {d:?}"),
        };
        let (f_tx, f_rx, f_ctrl) = waiter();
        assert!(matches!(c.begin(Op::Div22, key, &f_tx, &f_ctrl), Decision::Follow { .. }));
        fill.fail(&ServiceError::Backend("kernel exploded".into()));
        match f_rx.try_recv().unwrap() {
            Err(ServiceError::Backend(msg)) => assert_eq!(msg, "kernel exploded"),
            other => panic!("expected backend error, got {other:?}"),
        }
        // the key is clear again: next dispatch leads fresh
        assert!(matches!(c.begin(Op::Div22, key, &lead_tx, &lead_ctrl), Decision::Lead));
    }

    #[test]
    fn dropped_unresolved_fill_unblocks_followers() {
        let c = Arc::new(ResultCache::with_budget(1 << 20));
        let key = 11;
        let (lead_tx, _lead_rx, lead_ctrl) = waiter();
        assert!(matches!(c.begin(Op::Add, key, &lead_tx, &lead_ctrl), Decision::Lead));
        let fill = CacheFill::new(c.clone(), Op::Add, key);
        let (f_tx, f_rx, f_ctrl) = waiter();
        assert!(matches!(c.begin(Op::Add, key, &f_tx, &f_ctrl), Decision::Follow { .. }));
        drop(fill); // leader dropped on shutdown without resolving
        assert!(matches!(f_rx.try_recv().unwrap(), Err(ServiceError::QueueClosed)));
    }

    #[test]
    fn eviction_respects_byte_budget() {
        // stripe budget = 4096 bytes; each entry is 64 + 2*(32+4*100)
        // = 928 bytes, so a stripe holds 4 entries and the 5th evicts
        let c = ResultCache::with_budget(4096 * CACHE_SHARDS);
        let (tx, _rx, ctrl) = waiter();
        for n in 0..6u64 {
            let key = same_stripe_key(n);
            assert!(matches!(c.begin(Op::Add22, key, &tx, &ctrl), Decision::Lead));
            c.fill_complete(Op::Add22, key, 0, &planes_of(100, n as f32), 0.01);
        }
        let s = c.stats();
        assert!(s.evictions >= 2, "evictions: {}", s.evictions);
        assert!(
            c.live_bytes() <= c.budget_bytes(),
            "live {} > budget {}",
            c.live_bytes(),
            c.budget_bytes()
        );
        // oldest entries gone, newest resident
        assert!(matches!(c.begin(Op::Add22, 0, &tx, &ctrl), Decision::Lead));
        assert!(matches!(c.begin(Op::Add22, 5, &tx, &ctrl), Decision::Hit { .. }));
    }

    #[test]
    fn rehit_promotes_out_of_eviction_order() {
        // stripe holds 2 entries of 928B within a 2048B budget
        let c = ResultCache::with_budget(2048 * CACHE_SHARDS);
        let (tx, _rx, ctrl) = waiter();
        for n in [1u64, 2] {
            c.begin(Op::Add22, n, &tx, &ctrl);
            c.fill_complete(Op::Add22, n, 0, &planes_of(100, n as f32), 0.01);
        }
        // touch 1: probation → protected; now 2 is the probation head
        assert!(matches!(c.begin(Op::Add22, 1, &tx, &ctrl), Decision::Hit { .. }));
        c.begin(Op::Add22, 3, &tx, &ctrl);
        c.fill_complete(Op::Add22, 3, 0, &planes_of(100, 3.0), 0.01);
        // plain LRU would evict 1 (oldest insert); segmented evicts 2
        assert!(matches!(c.begin(Op::Add22, 1, &tx, &ctrl), Decision::Hit { .. }));
        assert!(matches!(c.begin(Op::Add22, 2, &tx, &ctrl), Decision::Lead));
    }

    #[test]
    fn eviction_prefers_cheap_to_recompute_entries() {
        let c = ResultCache::with_budget(2048 * CACHE_SHARDS);
        let (tx, _rx, ctrl) = waiter();
        // same bytes, wildly different measured cost
        c.begin(Op::Div22, 1, &tx, &ctrl);
        c.fill_complete(Op::Div22, 1, 0, &planes_of(100, 1.0), 0.5); // expensive
        c.begin(Op::Add22, 2, &tx, &ctrl);
        c.fill_complete(Op::Add22, 2, 0, &planes_of(100, 2.0), 1e-5); // cheap
        c.begin(Op::Add22, 3, &tx, &ctrl);
        c.fill_complete(Op::Add22, 3, 0, &planes_of(100, 3.0), 0.01);
        // LRU head is 1, but 2 is far cheaper per byte: 2 goes first
        assert!(matches!(c.begin(Op::Div22, 1, &tx, &ctrl), Decision::Hit { .. }));
        assert!(matches!(c.begin(Op::Add22, 2, &tx, &ctrl), Decision::Lead));
    }

    #[test]
    fn oversize_results_are_not_cached() {
        let c = ResultCache::with_budget(1024 * CACHE_SHARDS);
        let (tx, _rx, ctrl) = waiter();
        c.begin(Op::Add22, 1, &tx, &ctrl);
        // 2 planes × 1000 lanes ≈ 8128 bytes > 1024 stripe budget
        c.fill_complete(Op::Add22, 1, 0, &planes_of(1000, 1.0), 0.01);
        assert_eq!(c.live_bytes(), 0);
        assert!(matches!(c.begin(Op::Add22, 1, &tx, &ctrl), Decision::Lead));
        let s = c.stats();
        assert_eq!(s.inserted_bytes, 0);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn follower_promotion_pops_most_recent() {
        let c = Arc::new(ResultCache::with_budget(1 << 20));
        let key = 21;
        let (lead_tx, _lead_rx, lead_ctrl) = waiter();
        assert!(matches!(c.begin(Op::Add, key, &lead_tx, &lead_ctrl), Decision::Lead));
        let mut fill = CacheFill::new(c.clone(), Op::Add, key);
        fill.set_shard(5);
        let (f_tx, f_rx, f_ctrl) = waiter();
        c.begin(Op::Add, key, &f_tx, &f_ctrl);
        let (tx, ctrl) = fill.pop_follower().expect("one follower parked");
        assert!(fill.pop_follower().is_none());
        assert!(!ctrl.is_cancelled());
        tx.send(Ok(vec![vec![1.0]])).unwrap();
        assert_eq!(f_rx.try_recv().unwrap().unwrap(), vec![vec![1.0]]);
        // resolve so Drop has nothing to fail
        fill.complete(vec![vec![1.0]], 0.0);
    }

    #[test]
    fn hit_rate_counts_coalesced_as_saved() {
        let s = CacheStats { hits: 6, misses: 2, coalesced: 2, ..CacheStats::default() };
        assert!((s.hit_rate() - 0.8).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }
}
