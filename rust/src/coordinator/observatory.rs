//! The accuracy observatory: GPU-model sweeps as a service.
//!
//! The paper's headline results are its accuracy tables — Table 2
//! (ulp-error intervals per arithmetic model) and Table 5 (max relative
//! error per operator) — measured once, offline, on a fixed grid. This
//! module turns that static evaluation into a **continuous
//! experiment**: a configurable fraction of live traffic is *mirrored*
//! onto a reference backend (native, correctly rounded float-float)
//! and one [`crate::backend::GpuSimBackend`] per observed GPU model
//! (`nv35`, `r300`, `chopped`, ...), replies are diffed lane by lane
//! with the ulp kernel ([`crate::backend::ulp`]), and per-(model, op)
//! statistics — min/max/mean ulp error, relative-error EWMAs, and a
//! worst-offender input capture — aggregate into lock-free
//! [`OpAccuracy`](crate::coordinator::metrics::OpAccuracy) cells that
//! [`crate::coordinator::Service::accuracy_report`] snapshots at any
//! moment.
//!
//! **Isolation.** Observation must never skew what it observes. The
//! mirrored copy of a request is an `Arc`-clone of its input planes
//! (no lanes copied), sent to a dedicated observatory thread *after*
//! the routing policy has placed the original on a shard. The
//! observatory owns its own backends — mirrored work never enters a
//! shard queue, never touches the per-shard
//! [`Telemetry`](crate::coordinator::metrics::Telemetry) that
//! `measured` routing reads, and never moves a queue-depth counter.
//! Backpressure is drop-not-block: when the observatory falls behind
//! its [`ObservatorySpec::max_pending_lanes`] budget, sampled mirrors
//! are dropped (and counted), and serving latency is unaffected.
//!
//! **Fusion-aware slicing.** Like the serving fusion stage, the
//! observatory packs same-op mirror jobs into padded launches over a
//! small ladder; outputs are sliced back per request before diffing,
//! so pad lanes — which compute on neutral fill values — are excluded
//! from every statistic (see [`crate::backend::ulp::diff_outputs`]).
//!
//! # Examples
//!
//! ```
//! use ffgpu::backend::{BackendSpec, Op};
//! use ffgpu::coordinator::{ObservatorySpec, Plan, Service, ServiceSpec};
//!
//! let spec = ServiceSpec::uniform(BackendSpec::native_single(), 1)
//!     .with_observatory(ObservatorySpec::new(1.0, ["nv35"]));
//! let svc = Service::start(spec)?;
//! let set = svc.handle().dispatch_mirrored(
//!     Plan::new(Op::Mul12, vec![vec![1.5; 64], vec![std::f32::consts::PI; 64]])?,
//! )?;
//! let (outputs, mirror) = set.wait()?;
//! assert_eq!(outputs.len(), 2);
//! assert_eq!(mirror.models[0].model, "nv35");
//! let report = svc.accuracy_report().expect("observatory armed");
//! assert!(report.row("nv35", Op::Mul12).is_some());
//! # Ok::<(), ffgpu::backend::ServiceError>(())
//! ```

use super::metrics::{OpAccuracy, WorstLane};
use super::plan::Ticket;
use crate::backend::native::DEFAULT_CHUNK;
use crate::backend::{
    ulp, ExecJob, GpuSimBackend, KernelBackend, NativeBackend, Op, ServiceError,
    UlpDiff,
};
use crate::gpusim::GpuModel;
use crate::harness::table::Table;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

/// Max mirror jobs drained into one observatory batch.
const MAX_DRAIN: usize = 64;

/// Configuration of the accuracy observatory, armed on a service via
/// [`crate::coordinator::ServiceSpec::with_observatory`] (CLI:
/// `--observe <fraction> --observe-models nv35,r300`).
#[derive(Clone, Debug)]
pub struct ObservatorySpec {
    /// Fraction of dispatched requests to mirror, in `[0, 1]`.
    /// Sampling is deterministic (a Bresenham accumulator over
    /// dispatches), so `0.25` mirrors exactly every 4th request.
    /// `0.0` disables sampling; forced mirrors
    /// ([`crate::coordinator::Handle::dispatch_mirrored`]) still run.
    pub fraction: f64,
    /// GPU arithmetic models to observe ([`GpuModel::by_name`] names:
    /// `ieee-rn`, `chopped`, `r300`, `nv35`, `nv40`). Must be
    /// non-empty; validated at service start.
    pub models: Vec<String>,
    /// Launch-size ladder for fused mirror launches (ascending after
    /// sanitisation; empty = exact-size launches, no padding).
    pub ladder: Vec<usize>,
    /// Backpressure budget: mirror lanes allowed in flight before
    /// sampled mirrors are dropped (and counted) instead of queued.
    /// Forced mirrors bypass the cap — their caller waits on the
    /// report.
    pub max_pending_lanes: usize,
}

impl ObservatorySpec {
    /// Default fused-mirror launch ladder (small: observation batches
    /// stay far below the serving ladder's 1M-lane launches).
    pub const DEFAULT_LADDER: [usize; 3] = [1024, 4096, 16384];

    /// Default [`ObservatorySpec::max_pending_lanes`] budget.
    pub const DEFAULT_MAX_PENDING_LANES: usize = 1 << 18;

    /// Observe `models` on `fraction` of live traffic, with the
    /// default ladder and backpressure budget.
    pub fn new<I, S>(fraction: f64, models: I) -> ObservatorySpec
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        ObservatorySpec {
            fraction,
            models: models.into_iter().map(Into::into).collect(),
            ladder: Self::DEFAULT_LADDER.to_vec(),
            max_pending_lanes: Self::DEFAULT_MAX_PENDING_LANES,
        }
    }

    /// Replace the fused-mirror launch ladder (empty = exact sizes).
    pub fn with_ladder(mut self, ladder: Vec<usize>) -> ObservatorySpec {
        self.ladder = ladder;
        self
    }

    /// Replace the backpressure budget.
    pub fn with_max_pending_lanes(mut self, lanes: usize) -> ObservatorySpec {
        self.max_pending_lanes = lanes;
        self
    }

    /// Parse the CLI pair `--observe <fraction>` /
    /// `--observe-models <comma-list>`.
    pub fn from_cli(fraction: &str, models: &str) -> Result<ObservatorySpec, ServiceError> {
        let f: f64 = fraction.parse().map_err(|_| {
            ServiceError::Backend(format!("bad --observe fraction '{fraction}'"))
        })?;
        let names: Vec<String> = models
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
        let spec = ObservatorySpec::new(f, names);
        spec.validate()?;
        Ok(spec)
    }

    /// Validate fraction range and model names (what
    /// [`crate::coordinator::Service::start`] enforces before spawning
    /// anything).
    pub fn validate(&self) -> Result<(), ServiceError> {
        if !self.fraction.is_finite() || !(0.0..=1.0).contains(&self.fraction) {
            return Err(ServiceError::Backend(format!(
                "observe fraction {} must be within [0, 1]",
                self.fraction
            )));
        }
        if self.models.is_empty() {
            return Err(ServiceError::Backend(
                "observatory needs at least one GPU model (--observe-models)".into(),
            ));
        }
        for m in &self.models {
            if GpuModel::by_name(m).is_none() {
                return Err(ServiceError::Backend(format!(
                    "unknown GPU model '{m}' in observatory spec"
                )));
            }
        }
        Ok(())
    }
}

/// One request's mirrored copy, riding the observatory channel.
pub(crate) struct MirrorJob {
    pub(crate) op: Op,
    pub(crate) inputs: Vec<Arc<Vec<f32>>>,
    pub(crate) len: usize,
    /// Armed by forced mirrors: the per-request diff goes back here.
    pub(crate) report: Option<mpsc::Sender<MirrorReport>>,
}

pub(crate) enum ObsMsg {
    Mirror(MirrorJob),
    /// Ack once every message queued before this one has been folded
    /// into the cells — what makes `accuracy_report()` deterministic.
    Flush(mpsc::Sender<()>),
    Shutdown,
}

/// The per-request diff a forced mirror reports back: one
/// [`UlpDiff`] per observed model, over this request's lanes only.
///
/// `models` is **empty** when the mirror could not run — the
/// observatory was gone or its reference execute failed — so a
/// serving reply is never held hostage by an observation failure.
#[derive(Clone, Debug)]
pub struct MirrorReport {
    pub op: Op,
    pub len: usize,
    pub models: Vec<ModelDiff>,
}

/// One model's lane-by-lane verdict on one mirrored request.
#[derive(Clone, Debug)]
pub struct ModelDiff {
    pub model: String,
    pub diff: UlpDiff,
}

/// A [`Ticket`] plus the receiver for its mirror's accuracy verdict —
/// what [`crate::coordinator::Handle::dispatch_mirrored`] returns.
#[derive(Debug)]
pub struct TicketSet {
    ticket: Ticket,
    report: mpsc::Receiver<MirrorReport>,
}

impl TicketSet {
    pub(crate) fn new(ticket: Ticket, report: mpsc::Receiver<MirrorReport>) -> TicketSet {
        TicketSet { ticket, report }
    }

    /// The serving-side ticket (shard attribution, deadline/cancel).
    pub fn ticket(&self) -> &Ticket {
        &self.ticket
    }

    /// Split into the ticket and the raw report receiver.
    pub fn into_parts(self) -> (Ticket, mpsc::Receiver<MirrorReport>) {
        (self.ticket, self.report)
    }

    /// Block for both the serving reply and the mirror's verdict. A
    /// serving reply that arrived is never discarded over a mirror
    /// failure: if the observatory died before reporting, the reply
    /// comes back with an empty [`MirrorReport::models`].
    pub fn wait(self) -> Result<(Vec<Vec<f32>>, MirrorReport), ServiceError> {
        let (op, len) = (self.ticket.op(), self.ticket.len());
        let out = self.ticket.wait()?;
        let rep = self
            .report
            .recv()
            .unwrap_or_else(|_| MirrorReport { op, len, models: Vec::new() });
        Ok((out, rep))
    }
}

/// Per-model accuracy cells (one [`OpAccuracy`] per catalogue op).
pub(crate) struct ModelCells {
    name: String,
    cells: [OpAccuracy; Op::COUNT],
}

/// Shared observatory control: the dispatch-side sampler/backpressure
/// plus the accuracy cells the observatory thread writes.
pub(crate) struct ObsCtl {
    /// Bresenham sampling step: `fraction * 2^32` per dispatch; a
    /// mirror fires whenever the 32-bit accumulator wraps.
    step: u64,
    acc: AtomicU64,
    pending_lanes: AtomicUsize,
    max_pending_lanes: usize,
    mirrored_requests: AtomicU64,
    mirrored_lanes: AtomicU64,
    dropped_requests: AtomicU64,
    errors: AtomicU64,
    models: Vec<ModelCells>,
}

impl ObsCtl {
    pub(crate) fn new(spec: &ObservatorySpec) -> ObsCtl {
        ObsCtl {
            step: (spec.fraction.clamp(0.0, 1.0) * 4294967296.0) as u64,
            acc: AtomicU64::new(0),
            pending_lanes: AtomicUsize::new(0),
            max_pending_lanes: spec.max_pending_lanes,
            mirrored_requests: AtomicU64::new(0),
            mirrored_lanes: AtomicU64::new(0),
            dropped_requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            models: spec
                .models
                .iter()
                .map(|name| ModelCells {
                    name: name.clone(),
                    cells: std::array::from_fn(|_| OpAccuracy::default()),
                })
                .collect(),
        }
    }

    /// Tick the sampler for one dispatch; true = mirror this one.
    pub(crate) fn sample(&self) -> bool {
        let prev = self.acc.fetch_add(self.step, Ordering::Relaxed);
        (prev & 0xFFFF_FFFF) + self.step >= 1 << 32
    }

    fn try_reserve(&self, lanes: usize, forced: bool) -> bool {
        // reserve first, undo if over budget: a load-then-add pair
        // would let concurrent dispatchers all observe the same low
        // value and collectively blow past the cap
        let prev = self.pending_lanes.fetch_add(lanes, Ordering::Relaxed);
        if !forced && prev + lanes > self.max_pending_lanes {
            self.pending_lanes.fetch_sub(lanes, Ordering::Relaxed);
            return false;
        }
        true
    }

    fn release(&self, lanes: usize) {
        self.pending_lanes.fetch_sub(lanes, Ordering::Relaxed);
    }

    fn note_errors(&self, n: u64) {
        self.errors.fetch_add(n, Ordering::Relaxed);
    }
}

/// The handle-side link to a running observatory: the job channel plus
/// the shared control block. Cloned into every
/// [`crate::coordinator::Handle`].
#[derive(Clone)]
pub(crate) struct ObsLink {
    pub(crate) tx: mpsc::Sender<ObsMsg>,
    pub(crate) ctl: Arc<ObsCtl>,
}

impl ObsLink {
    /// Enqueue one mirror (already sampled, or forced when `report` is
    /// armed). Returns false when backpressure dropped it or the
    /// observatory is gone.
    pub(crate) fn send_mirror(
        &self, op: Op, inputs: Vec<Arc<Vec<f32>>>, len: usize,
        report: Option<mpsc::Sender<MirrorReport>>,
    ) -> bool {
        let forced = report.is_some();
        if !self.ctl.try_reserve(len, forced) {
            self.ctl.dropped_requests.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // count before the send: a Flush queued behind this mirror
        // folds its lanes into the cells, so a report taken then must
        // already include them in the mirrored_* totals
        self.ctl.mirrored_requests.fetch_add(1, Ordering::Relaxed);
        self.ctl.mirrored_lanes.fetch_add(len as u64, Ordering::Relaxed);
        if self.tx.send(ObsMsg::Mirror(MirrorJob { op, inputs, len, report })).is_err() {
            self.ctl.mirrored_requests.fetch_sub(1, Ordering::Relaxed);
            self.ctl.mirrored_lanes.fetch_sub(len as u64, Ordering::Relaxed);
            self.ctl.release(len);
            return false;
        }
        true
    }
}

/// Spawn the observatory thread (reference + per-model backends are
/// built on the thread, like shard backends).
pub(crate) fn spawn(
    spec: ObservatorySpec, ctl: Arc<ObsCtl>, rx: mpsc::Receiver<ObsMsg>,
) -> Result<JoinHandle<()>, ServiceError> {
    std::thread::Builder::new()
        .name("ffgpu-observatory".into())
        .spawn(move || observatory_thread(spec, ctl, rx))
        .map_err(|e| ServiceError::Backend(format!("spawn observatory: {e}")))
}

fn observatory_thread(spec: ObservatorySpec, ctl: Arc<ObsCtl>, rx: mpsc::Receiver<ObsMsg>) {
    // single-worker native reference: correctly rounded float-float,
    // deterministic, and never competing with the serving shards' crews
    let mut reference: Box<dyn KernelBackend> =
        Box::new(NativeBackend::new(DEFAULT_CHUNK, 1));
    let mut models: Vec<Box<dyn KernelBackend>> = Vec::with_capacity(spec.models.len());
    for name in &spec.models {
        match GpuSimBackend::by_name(name) {
            Ok(b) => models.push(Box::new(b)),
            // names were validated at Service::start; a failure here
            // means the model set changed under us — bail out cleanly
            Err(_) => return,
        }
    }
    let mut ladder = spec.ladder.clone();
    ladder.retain(|&s| s > 0);
    ladder.sort_unstable();
    ladder.dedup();

    loop {
        let mut jobs: Vec<MirrorJob> = Vec::new();
        let mut flushes: Vec<mpsc::Sender<()>> = Vec::new();
        let mut shutdown = false;
        match rx.recv() {
            Ok(ObsMsg::Mirror(j)) => jobs.push(j),
            Ok(ObsMsg::Flush(tx)) => flushes.push(tx),
            Ok(ObsMsg::Shutdown) | Err(_) => break,
        }
        while jobs.len() < MAX_DRAIN {
            match rx.try_recv() {
                Ok(ObsMsg::Mirror(j)) => jobs.push(j),
                Ok(ObsMsg::Flush(tx)) => flushes.push(tx),
                Ok(ObsMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // group by operator, preserving arrival order (same shape as
        // the shard serve loop's fusion stage)
        let mut groups: Vec<(Op, Vec<MirrorJob>)> = Vec::new();
        for j in jobs {
            match groups.iter().position(|(op, _)| *op == j.op) {
                Some(i) => groups[i].1.push(j),
                None => groups.push((j.op, vec![j])),
            }
        }
        for (op, group) in groups {
            run_group(op, &group, reference.as_mut(), &mut models, &ladder, &ctl);
        }
        for f in flushes {
            let _ = f.send(());
        }
        if shutdown {
            break;
        }
    }
}

/// Execute one fused mirror group on the reference and every model,
/// slice the launch back per request, and fold the diffs into the
/// accuracy cells.
fn run_group(
    op: Op, jobs: &[MirrorJob], reference: &mut dyn KernelBackend,
    models: &mut [Box<dyn KernelBackend>], ladder: &[usize], ctl: &ObsCtl,
) {
    let (n_in, n_out) = op.arity();
    let total: usize = jobs.iter().map(|j| j.len).sum();
    // pad the concatenation up to the smallest ladder rung that fits;
    // exact size when no rung does (or no ladder is configured)
    let size = ladder.iter().copied().find(|&s| s >= total).unwrap_or(total);
    let mut planes: Vec<Arc<Vec<f32>>> = Vec::with_capacity(n_in);
    for p in 0..n_in {
        let mut buf = Vec::with_capacity(size);
        for j in jobs {
            buf.extend_from_slice(&j.inputs[p]);
        }
        buf.resize(size, op.pad_value(p));
        planes.push(Arc::new(buf));
    }
    let job = match ExecJob::from_shared(op, planes) {
        Ok(j) => j,
        Err(_) => {
            // unreachable for planes the coordinator validated, but an
            // observatory bug must not kill the thread — and forced
            // mirrors still get their (empty) report
            ctl.note_errors(1);
            for j in jobs {
                if let Some(tx) = &j.report {
                    let _ = tx.send(MirrorReport { op, len: j.len, models: Vec::new() });
                }
            }
            ctl.release(total);
            return;
        }
    };
    let mut ref_outs = vec![vec![0.0f32; size]; n_out];
    if reference.execute(&job, &mut ref_outs).is_err() {
        ctl.note_errors(1);
        for j in jobs {
            if let Some(tx) = &j.report {
                let _ = tx.send(MirrorReport { op, len: j.len, models: Vec::new() });
            }
        }
        ctl.release(total);
        return;
    }
    // run every model over the same fused launch
    let mut model_outs: Vec<Option<Vec<Vec<f32>>>> = Vec::with_capacity(models.len());
    for b in models.iter_mut() {
        let mut outs = vec![vec![0.0f32; size]; n_out];
        match b.execute(&job, &mut outs) {
            Ok(_) => model_outs.push(Some(outs)),
            Err(_) => {
                ctl.note_errors(1);
                model_outs.push(None);
            }
        }
    }
    // slice the launch back per request: pad lanes (beyond `total`)
    // and neighbouring requests never reach a diff
    let mut offset = 0usize;
    for j in jobs {
        let in_refs: Vec<&[f32]> = j.inputs.iter().map(|p| p.as_slice()).collect();
        let mut diffs: Vec<ModelDiff> = Vec::with_capacity(models.len());
        for (mi, outs) in model_outs.iter().enumerate() {
            let Some(outs) = outs else { continue };
            let d = ulp::diff_outputs(op, &ref_outs, outs, offset, j.len);
            let worst = capture_worst(&d, &in_refs, outs, &ref_outs, offset);
            ctl.models[mi].cells[op.index()].record(&d, worst);
            diffs.push(ModelDiff { model: ctl.models[mi].name.clone(), diff: d });
        }
        if let Some(tx) = &j.report {
            let _ = tx.send(MirrorReport { op, len: j.len, models: diffs });
        }
        offset += j.len;
    }
    ctl.release(total);
}

/// Materialise the worst lane of a diff as a [`WorstLane`] capture
/// (`None` when the slice was exact). `base` offsets into the output
/// planes, which belong to the fused launch; the input planes are the
/// request's own, so they index at the bare lane.
fn capture_worst(
    d: &UlpDiff, inputs: &[&[f32]], got: &[Vec<f32>], reference: &[Vec<f32>],
    base: usize,
) -> Option<WorstLane> {
    let lane = d.worst_lane?;
    if d.worst_abs_ulp() == 0.0 {
        return None;
    }
    Some(WorstLane {
        ulp: d.worst_ulp,
        rel: d.worst_rel,
        inputs: inputs.iter().map(|p| p[lane]).collect(),
        got: got.iter().map(|p| p[base + lane]).collect(),
        reference: reference.iter().map(|p| p[base + lane]).collect(),
    })
}

/// One (model, op) row of an [`AccuracyReport`].
#[derive(Clone, Debug)]
pub struct OpAccuracyRow {
    pub op: Op,
    /// Lanes compared so far. 0 with [`OpAccuracyRow::non_finite`]
    /// nonzero means every observed lane was NaN/inf — the statistics
    /// are all zero and the renderers flag the cell as "non-finite".
    pub lanes: u64,
    /// Diff groups folded in (the EWMA's sample count).
    pub groups: u64,
    /// Non-finite lanes excluded from the statistics.
    pub non_finite: u64,
    pub min_ulp: f64,
    pub max_ulp: f64,
    pub mean_abs_ulp: f64,
    /// Largest relative error observed.
    pub max_rel: f64,
    /// EWMA of per-group max relative error.
    pub rel_ewma: f64,
    /// The captured worst-offender lane, when any error was observed.
    pub worst: Option<WorstLane>,
}

impl OpAccuracyRow {
    /// `log2(max_rel)` — the paper's Table 5 notation. `None` when no
    /// error was ever observed ("(exact)").
    pub fn max_rel_log2(&self) -> Option<f64> {
        if self.max_rel > 0.0 {
            Some(self.max_rel.log2())
        } else {
            None
        }
    }

    /// Table 5 cell formatting: "-45.0" or "(exact)".
    pub fn display_rel(&self) -> String {
        match self.max_rel_log2() {
            Some(v) => format!("{v:.1}"),
            None => "(exact)".to_string(),
        }
    }
}

fn row_from_cell(op: Op, c: &OpAccuracy) -> Option<OpAccuracyRow> {
    let lanes = c.lanes();
    // a cell whose every lane was non-finite still observed traffic —
    // a model that overflows 100% of the time must surface as a red
    // flag ("non-finite" in the tables), not as "never observed"
    if lanes == 0 && c.non_finite() == 0 {
        return None;
    }
    Some(OpAccuracyRow {
        op,
        lanes,
        groups: c.groups(),
        non_finite: c.non_finite(),
        min_ulp: c.min_ulp().unwrap_or(0.0),
        max_ulp: c.max_ulp().unwrap_or(0.0),
        mean_abs_ulp: c.mean_abs_ulp().unwrap_or(0.0),
        max_rel: c.max_rel().unwrap_or(0.0),
        rel_ewma: c.rel_ewma().unwrap_or(0.0),
        worst: c.worst(),
    })
}

/// One observed model's rows, in catalogue order (cold ops omitted).
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub model: String,
    pub rows: Vec<OpAccuracyRow>,
}

/// A point-in-time snapshot of the observatory's accuracy surface,
/// from [`crate::coordinator::Service::accuracy_report`].
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    /// One report per observed model, in spec order.
    pub models: Vec<ModelReport>,
    pub mirrored_requests: u64,
    pub mirrored_lanes: u64,
    /// Sampled mirrors dropped by backpressure.
    pub dropped_requests: u64,
    /// Observatory-side execute failures.
    pub observatory_errors: u64,
    /// Serving-plane shard tiers `(label, kernel tier)` in shard
    /// order, filled by [`crate::coordinator::Service::accuracy_report`]
    /// so rendered reports state which CPU kernel tier produced the
    /// traffic the observatory mirrored (`None` on substrates without
    /// tiers — gpusim, XLA).
    pub serving_tiers: Vec<(String, Option<crate::backend::KernelTier>)>,
}

impl AccuracyReport {
    pub(crate) fn collect(ctl: &ObsCtl) -> AccuracyReport {
        AccuracyReport {
            models: ctl
                .models
                .iter()
                .map(|mc| ModelReport {
                    model: mc.name.clone(),
                    rows: Op::ALL
                        .iter()
                        .filter_map(|&op| row_from_cell(op, &mc.cells[op.index()]))
                        .collect(),
                })
                .collect(),
            mirrored_requests: ctl.mirrored_requests.load(Ordering::Relaxed),
            mirrored_lanes: ctl.mirrored_lanes.load(Ordering::Relaxed),
            dropped_requests: ctl.dropped_requests.load(Ordering::Relaxed),
            observatory_errors: ctl.errors.load(Ordering::Relaxed),
            serving_tiers: Vec::new(),
        }
    }

    /// The row for `(model, op)`, if that cell has seen lanes.
    pub fn row(&self, model: &str, op: Op) -> Option<&OpAccuracyRow> {
        self.models
            .iter()
            .find(|m| m.model == model)?
            .rows
            .iter()
            .find(|r| r.op == op)
    }

    /// Union of observed operators, in catalogue order.
    pub fn observed_ops(&self) -> Vec<Op> {
        Op::ALL
            .into_iter()
            .filter(|&op| self.models.iter().any(|m| m.rows.iter().any(|r| r.op == op)))
            .collect()
    }

    fn footer(&self) -> String {
        let mut out = format!(
            "mirrored: {} requests / {} lanes  dropped: {}  observatory errors: {}\n",
            self.mirrored_requests,
            self.mirrored_lanes,
            self.dropped_requests,
            self.observatory_errors
        );
        if !self.serving_tiers.is_empty() {
            out.push_str("serving tiers: ");
            for (i, (label, tier)) in self.serving_tiers.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                match tier {
                    Some(t) => out.push_str(&format!("{}={}", label, t.name())),
                    None => out.push_str(&format!("{}=-", label)),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render the live Table-2 analogue: per-(model, op) ulp-error
    /// intervals observed under mirrored traffic.
    pub fn render_table2_live(&self) -> String {
        let mut header: Vec<String> = vec!["Operator".to_string()];
        header.extend(self.models.iter().map(|m| m.model.clone()));
        header.push("lanes".to_string());
        let refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(
            "Table 2 (live) — observed ulp-error intervals vs the native \
             float-float reference",
            &refs,
        );
        for op in self.observed_ops() {
            let mut cells = vec![op.name().to_string()];
            let mut lanes = 0u64;
            for m in &self.models {
                match m.rows.iter().find(|r| r.op == op) {
                    Some(r) if r.lanes == 0 => {
                        // every compared lane was NaN/inf: no interval
                        // exists, but the breakage must be visible
                        lanes = lanes.max(r.non_finite);
                        cells.push(format!("non-finite x{}", r.non_finite));
                    }
                    Some(r) => {
                        lanes = lanes.max(r.lanes);
                        let mut cell =
                            format!("[{:+.2}, {:+.2}]", r.min_ulp, r.max_ulp);
                        if r.non_finite > 0 {
                            cell.push_str(&format!(" (+{} non-finite)", r.non_finite));
                        }
                        cells.push(cell);
                    }
                    None => cells.push("-".to_string()),
                }
            }
            cells.push(lanes.to_string());
            t.row(cells);
        }
        let mut out = t.render();
        out.push_str(&self.footer());
        out
    }

    /// Render the live Table-5 analogue: per-(model, op) max observed
    /// `log2` relative error ("(exact)" when no error was seen).
    pub fn render_table5_live(&self) -> String {
        let mut header: Vec<String> = vec!["Operator".to_string()];
        header.extend(self.models.iter().map(|m| m.model.clone()));
        let refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(
            "Table 5 (live) — max observed log2 relative error under \
             mirrored traffic",
            &refs,
        );
        for op in self.observed_ops() {
            let mut cells = vec![op.name().to_string()];
            for m in &self.models {
                match m.rows.iter().find(|r| r.op == op) {
                    Some(r) if r.lanes == 0 => cells.push("non-finite".to_string()),
                    Some(r) => cells.push(r.display_rel()),
                    None => cells.push("-".to_string()),
                }
            }
            t.row(cells);
        }
        let mut out = t.render();
        out.push_str(&self.footer());
        out
    }
}

/// The one-shot counterpart of the live observatory: sweep `total`
/// lanes of the standard workload ([`crate::harness::workload`]) for
/// `op` under `model`, chunked like the Table 5 harness, and return
/// the same row the live report would. The integration suite pins
/// live == one-shot over identical streams.
pub fn one_shot_sweep(
    model: &str, op: Op, total: usize, chunk: usize, seed: u64,
) -> Result<OpAccuracyRow, ServiceError> {
    let mut reference = NativeBackend::new(DEFAULT_CHUNK, 1);
    let mut target = GpuSimBackend::by_name(model)?;
    let cell = OpAccuracy::default();
    let chunk = chunk.max(1);
    let mut done = 0usize;
    let mut idx = 0u64;
    while done < total {
        let n = chunk.min(total - done);
        let planes = crate::harness::workload::planes_for(op.name(), n, seed ^ (idx << 20));
        let in_refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let job = ExecJob::new(op, planes.clone())?;
        let mut ref_outs = vec![vec![0.0f32; n]; op.n_out()];
        reference.execute(&job, &mut ref_outs)?;
        let mut got = vec![vec![0.0f32; n]; op.n_out()];
        target.execute(&job, &mut got)?;
        let d = ulp::diff_outputs(op, &ref_outs, &got, 0, n);
        let worst = capture_worst(&d, &in_refs, &got, &ref_outs, 0);
        cell.record(&d, worst);
        done += n;
        idx += 1;
    }
    row_from_cell(op, &cell)
        .ok_or_else(|| ServiceError::Backend("one-shot sweep compared no lanes".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendSpec;
    use crate::coordinator::{Plan, Service, ServiceSpec};
    use crate::harness::workload;

    fn observed_service(fraction: f64, models: &[&str]) -> Service {
        Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_observatory(ObservatorySpec::new(fraction, models.iter().copied())),
        )
        .unwrap()
    }

    #[test]
    fn spec_validates_models_and_fraction() {
        assert!(ObservatorySpec::new(0.5, ["nv35"]).validate().is_ok());
        assert!(ObservatorySpec::new(1.5, ["nv35"]).validate().is_err());
        assert!(ObservatorySpec::new(-0.1, ["nv35"]).validate().is_err());
        assert!(ObservatorySpec::new(f64::NAN, ["nv35"]).validate().is_err());
        assert!(ObservatorySpec::new(0.5, Vec::<String>::new()).validate().is_err());
        assert!(ObservatorySpec::new(0.5, ["voodoo2"]).validate().is_err());
        let cli = ObservatorySpec::from_cli("0.25", "nv35, r300").unwrap();
        assert_eq!(cli.fraction, 0.25);
        assert_eq!(cli.models, vec!["nv35", "r300"]);
        assert!(ObservatorySpec::from_cli("lots", "nv35").is_err());
        assert!(ObservatorySpec::from_cli("0.5", "").is_err());
    }

    #[test]
    fn unknown_model_fails_service_startup() {
        let err = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_observatory(ObservatorySpec::new(1.0, ["voodoo2"])),
        )
        .err()
        .expect("startup must fail");
        assert!(matches!(err, ServiceError::Backend(_)));
    }

    #[test]
    fn mirrored_dispatch_reports_per_model_diffs() {
        // fraction 0: only the forced mirror runs, so the counters are
        // exactly the one request below
        let svc = observed_service(0.0, &["ieee-rn", "nv35"]);
        let h = svc.handle();
        let n = 2048;
        let planes = workload::planes_for("add22", n, 0xB0B);
        let set = h.dispatch_mirrored(Plan::new(Op::Add22, planes).unwrap()).unwrap();
        let (out, rep) = set.wait().unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(rep.op, Op::Add22);
        assert_eq!(rep.len, n);
        assert_eq!(rep.models.len(), 2);
        // gpusim's IEEE model is bit-identical to native on add22
        let ieee = rep.models.iter().find(|m| m.model == "ieee-rn").unwrap();
        assert!(ieee.diff.is_exact(), "{:?}", ieee.diff);
        // nv35 truncated adds must deviate somewhere in 2048 lanes
        let nv35 = rep.models.iter().find(|m| m.model == "nv35").unwrap();
        assert!(nv35.diff.worst_abs_ulp() > 0.0, "{:?}", nv35.diff);
        let report = svc.accuracy_report().expect("observatory armed");
        assert_eq!(report.mirrored_requests, 1);
        assert_eq!(report.mirrored_lanes, n as u64);
        assert_eq!(report.dropped_requests, 0);
        assert_eq!(report.observatory_errors, 0);
        let row = report.row("nv35", Op::Add22).unwrap();
        assert_eq!(row.lanes, n as u64);
        assert!(row.worst.is_some(), "worst-offender capture missing");
        let w = row.worst.as_ref().unwrap();
        assert_eq!(w.inputs.len(), 4);
        assert_eq!(w.got.len(), 2);
        assert_eq!(report.row("ieee-rn", Op::Add22).unwrap().max_ulp, 0.0);
        // ops never mirrored stay out of the report
        assert!(report.row("nv35", Op::Div22).is_none());
    }

    #[test]
    fn sampling_follows_the_fraction() {
        let svc = observed_service(0.25, &["ieee-rn"]);
        let h = svc.handle();
        for _ in 0..8 {
            h.dispatch(Plan::new(Op::Add, vec![vec![1.0; 64], vec![2.0; 64]]).unwrap())
                .unwrap()
                .wait()
                .unwrap();
        }
        let rep = svc.accuracy_report().unwrap();
        assert_eq!(rep.mirrored_requests, 2, "8 dispatches at fraction 1/4");
        assert_eq!(rep.mirrored_lanes, 2 * 64);
        // fraction 0 never samples
        let svc = observed_service(0.0, &["ieee-rn"]);
        let h = svc.handle();
        for _ in 0..8 {
            h.dispatch(Plan::new(Op::Add, vec![vec![1.0; 8], vec![2.0; 8]]).unwrap())
                .unwrap()
                .wait()
                .unwrap();
        }
        assert_eq!(svc.accuracy_report().unwrap().mirrored_requests, 0);
    }

    #[test]
    fn fused_mirror_launches_exclude_pad_lanes() {
        // a 64-lane ladder pads both tiny mirrors; the ieee model is
        // bit-identical to native on add22, so any pad lane leaking
        // into the diff would surface as phantom error or extra lanes
        let spec = ServiceSpec::uniform(BackendSpec::native_single(), 1)
            .with_observatory(
                ObservatorySpec::new(0.0, ["ieee-rn"]).with_ladder(vec![64]),
            );
        let svc = Service::start(spec).unwrap();
        let h = svc.handle();
        for n in [3usize, 5] {
            let planes = workload::planes_for("add22", n, n as u64);
            let (_, rep) = h
                .dispatch_mirrored(Plan::new(Op::Add22, planes).unwrap())
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(rep.models[0].diff.lanes, n as u64);
            assert!(rep.models[0].diff.is_exact(), "{:?}", rep.models[0].diff);
        }
        let report = svc.accuracy_report().unwrap();
        let row = report.row("ieee-rn", Op::Add22).unwrap();
        assert_eq!(row.lanes, 8);
        assert_eq!(row.max_ulp, 0.0);
        assert_eq!(row.min_ulp, 0.0);
    }

    #[test]
    fn report_renders_live_tables() {
        let svc = observed_service(0.0, &["nv35", "r300"]);
        let h = svc.handle();
        for op in [Op::Add22, Op::Mul12] {
            let planes = workload::planes_for(op.name(), 256, 7);
            h.dispatch_mirrored(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
        }
        let rep = svc.accuracy_report().unwrap();
        let t2 = rep.render_table2_live();
        assert!(t2.contains("add22") && t2.contains("mul12"), "{t2}");
        assert!(t2.contains("nv35") && t2.contains("r300"), "{t2}");
        assert!(t2.contains("mirrored: 2 requests"), "{t2}");
        let t5 = rep.render_table5_live();
        assert!(t5.contains("add22") && t5.contains("mul12"), "{t5}");
        assert_eq!(rep.observed_ops(), vec![Op::Mul12, Op::Add22]);
    }

    #[test]
    fn all_non_finite_cells_stay_visible() {
        // a model that overflowed every observed lane must render as a
        // red flag, not vanish from the report as "never observed"
        let cell = OpAccuracy::default();
        cell.record(
            &UlpDiff { non_finite: 16, ..UlpDiff::default() },
            None,
        );
        let row = row_from_cell(Op::Mul22, &cell).expect("row must surface");
        assert_eq!(row.lanes, 0);
        assert_eq!(row.non_finite, 16);
        let rep = AccuracyReport {
            models: vec![ModelReport { model: "chopped".into(), rows: vec![row] }],
            mirrored_requests: 1,
            mirrored_lanes: 16,
            dropped_requests: 0,
            observatory_errors: 0,
        };
        assert_eq!(rep.observed_ops(), vec![Op::Mul22]);
        let t2 = rep.render_table2_live();
        assert!(t2.contains("non-finite x16"), "{t2}");
        let t5 = rep.render_table5_live();
        assert!(t5.contains("non-finite"), "{t5}");
        // a wholly cold cell still yields no row
        assert!(row_from_cell(Op::Add, &OpAccuracy::default()).is_none());
    }

    #[test]
    fn one_shot_sweep_matches_expectations() {
        let ieee = one_shot_sweep("ieee-rn", Op::Add22, 1024, 256, 3).unwrap();
        assert_eq!(ieee.lanes, 1024);
        assert_eq!(ieee.max_ulp, 0.0);
        assert_eq!(ieee.min_ulp, 0.0);
        assert!(ieee.max_rel_log2().is_none());
        assert_eq!(ieee.display_rel(), "(exact)");
        let nv35 = one_shot_sweep("nv35", Op::Add22, 1024, 256, 3).unwrap();
        assert_eq!(nv35.lanes, 1024);
        assert!(
            nv35.max_ulp > 0.0 || nv35.min_ulp < 0.0,
            "nv35 truncated adds should deviate: {nv35:?}"
        );
        assert!(nv35.max_rel_log2().is_some());
        assert!(one_shot_sweep("voodoo2", Op::Add22, 64, 64, 1).is_err());
    }
}
