//! Request/response types of the coordinator.

use crate::backend::ServiceError;
use std::sync::mpsc;

/// Result planes (one `Vec<f32>` per output plane) or a typed failure.
pub type OpResult = Result<Vec<Vec<f32>>, ServiceError>;

/// A stream-operator request: `op` applied elementwise to `inputs`
/// (arity must match the operator; every plane the same length).
#[derive(Debug)]
pub struct OpRequest {
    pub op: String,
    pub inputs: Vec<Vec<f32>>,
    /// One-shot reply channel.
    pub reply: mpsc::Sender<OpResult>,
}

impl OpRequest {
    /// Elements per plane.
    pub fn len(&self) -> usize {
        self.inputs.first().map_or(0, Vec::len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate arity/shape against the backend catalogue.
    pub fn validate(&self) -> Result<(), ServiceError> {
        let spec = crate::backend::op_spec(&self.op)
            .ok_or_else(|| ServiceError::UnknownOp(self.op.clone()))?;
        if self.inputs.len() != spec.n_in {
            return Err(ServiceError::Arity {
                op: self.op.clone(),
                want: spec.n_in,
                got: self.inputs.len(),
            });
        }
        let n = self.len();
        if self.inputs.iter().any(|p| p.len() != n) {
            return Err(ServiceError::Shape(
                "input planes have differing lengths".into(),
            ));
        }
        if n == 0 {
            return Err(ServiceError::Shape("empty request".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(op: &str, planes: usize, n: usize) -> (OpRequest, mpsc::Receiver<OpResult>) {
        let (tx, rx) = mpsc::channel();
        (OpRequest { op: op.into(), inputs: vec![vec![1.0; n]; planes], reply: tx }, rx)
    }

    #[test]
    fn validates_arity() {
        let (r, _rx) = req("add22", 4, 16);
        assert!(r.validate().is_ok());
        let (r, _rx) = req("add22", 3, 16);
        assert!(matches!(r.validate(), Err(ServiceError::Arity { want: 4, got: 3, .. })));
        let (r, _rx) = req("blorp", 2, 16);
        assert!(matches!(r.validate(), Err(ServiceError::UnknownOp(_))));
    }

    #[test]
    fn rejects_ragged_and_empty() {
        let (tx, _rx) = mpsc::channel();
        let r = OpRequest {
            op: "add".into(),
            inputs: vec![vec![1.0; 4], vec![1.0; 5]],
            reply: tx,
        };
        assert!(matches!(r.validate(), Err(ServiceError::Shape(_))));
        let (r, _rx) = req("add", 2, 0);
        assert!(matches!(r.validate(), Err(ServiceError::Shape(_))));
    }
}
