//! Request/response types of the coordinator.

use std::sync::mpsc;

/// Result planes (one `Vec<f32>` per output plane).
pub type OpResult = Result<Vec<Vec<f32>>, String>;

/// A stream-operator request: `op` applied elementwise to `inputs`
/// (arity must match the operator; every plane the same length).
#[derive(Debug)]
pub struct OpRequest {
    pub op: String,
    pub inputs: Vec<Vec<f32>>,
    /// One-shot reply channel.
    pub reply: mpsc::Sender<OpResult>,
}

impl OpRequest {
    /// Elements per plane.
    pub fn len(&self) -> usize {
        self.inputs.first().map_or(0, Vec::len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate arity/shape against the op table.
    pub fn validate(&self) -> Result<(), String> {
        let (n_in, _) = super::batcher::op_arity(&self.op)
            .ok_or_else(|| format!("unknown op '{}'", self.op))?;
        if self.inputs.len() != n_in {
            return Err(format!(
                "op '{}' wants {n_in} input planes, got {}", self.op, self.inputs.len()
            ));
        }
        let n = self.len();
        if self.inputs.iter().any(|p| p.len() != n) {
            return Err("input planes have differing lengths".into());
        }
        if n == 0 {
            return Err("empty request".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(op: &str, planes: usize, n: usize) -> (OpRequest, mpsc::Receiver<OpResult>) {
        let (tx, rx) = mpsc::channel();
        (OpRequest { op: op.into(), inputs: vec![vec![1.0; n]; planes], reply: tx }, rx)
    }

    #[test]
    fn validates_arity() {
        let (r, _rx) = req("add22", 4, 16);
        assert!(r.validate().is_ok());
        let (r, _rx) = req("add22", 3, 16);
        assert!(r.validate().is_err());
        let (r, _rx) = req("blorp", 2, 16);
        assert!(r.validate().is_err());
    }

    #[test]
    fn rejects_ragged_and_empty() {
        let (tx, _rx) = mpsc::channel();
        let r = OpRequest {
            op: "add".into(),
            inputs: vec![vec![1.0; 4], vec![1.0; 5]],
            reply: tx,
        };
        assert!(r.validate().is_err());
        let (r, _rx) = req("add", 2, 0);
        assert!(r.validate().is_err());
    }
}
