//! Request/response types of the coordinator.
//!
//! Since the Op/Plan redesign the wire unit is typed: an [`OpRequest`]
//! carries an [`Op`] (not a string), and the shape rules live in
//! [`Op::validate_planes`] — the single source shared by
//! [`OpRequest::validate`], the build-time check in
//! [`crate::coordinator::plan::Plan`], and the backends' own
//! `execute` validation.

use super::cache::CacheFill;
use super::plan::TicketState;
use crate::backend::{Op, ServiceError};
use std::sync::{mpsc, Arc};

/// Result planes (one `Vec<f32>` per output plane) or a typed failure.
pub type OpResult = Result<Vec<Vec<f32>>, ServiceError>;

/// A stream-operator request: `op` applied elementwise to `inputs`
/// (arity must match the operator; every plane the same length).
///
/// Input planes are `Arc`-shared: the fusion stage turns them into
/// [`crate::backend::ExecJob`]s without copying a lane, and persistent
/// backend workers hold clones across the batch.
#[derive(Debug)]
pub struct OpRequest {
    pub op: Op,
    pub inputs: Vec<Arc<Vec<f32>>>,
    /// One-shot reply channel.
    pub reply: mpsc::Sender<OpResult>,
    /// Lifecycle state shared with the client's
    /// [`crate::coordinator::Ticket`]: the shard serve loop checks it
    /// before executing and skips cancelled/expired requests.
    pub ctrl: Arc<TicketState>,
    /// Present when this request *leads* a result-cache miss: the
    /// shard must resolve it exactly once (insert + fan out to
    /// single-flight followers on success, share the error on
    /// failure). `None` for cache-off, forced-measurement and follower
    /// dispatches.
    pub(crate) fill: Option<CacheFill>,
}

impl OpRequest {
    /// Build a request with a fresh (un-cancelled, deadline-free)
    /// lifecycle state. Each plane moves into its own `Arc` (no lane
    /// is copied).
    pub fn new(op: Op, inputs: Vec<Vec<f32>>, reply: mpsc::Sender<OpResult>) -> OpRequest {
        OpRequest {
            op,
            inputs: inputs.into_iter().map(Arc::new).collect(),
            reply,
            ctrl: Arc::new(TicketState::new()),
            fill: None,
        }
    }

    /// Elements per plane.
    pub fn len(&self) -> usize {
        self.inputs.first().map_or(0, |p| p.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate arity/shape against the operator
    /// ([`Op::validate_planes`]). Each failure is a *specific*
    /// [`ServiceError`] variant — the seed folded ragged and empty
    /// batches into an opaque `Shape(String)` (and older still, let
    /// them panic inside backends).
    pub fn validate(&self) -> Result<(), ServiceError> {
        let refs: Vec<&[f32]> = self.inputs.iter().map(|p| p.as_slice()).collect();
        self.op.validate_planes(&refs).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(op: Op, planes: usize, n: usize) -> (OpRequest, mpsc::Receiver<OpResult>) {
        let (tx, rx) = mpsc::channel();
        (OpRequest::new(op, vec![vec![1.0; n]; planes], tx), rx)
    }

    #[test]
    fn validates_arity() {
        let (r, _rx) = req(Op::Add22, 4, 16);
        assert!(r.validate().is_ok());
        let (r, _rx) = req(Op::Add22, 3, 16);
        assert!(matches!(r.validate(), Err(ServiceError::Arity { want: 4, got: 3, .. })));
    }

    #[test]
    fn rejects_ragged_planes_with_the_specific_variant() {
        let (tx, _rx) = mpsc::channel();
        let r = OpRequest::new(Op::Add, vec![vec![1.0; 4], vec![1.0; 5]], tx);
        assert_eq!(
            r.validate().unwrap_err(),
            ServiceError::RaggedPlanes { op: Op::Add, plane: 1, want: 4, got: 5 }
        );
        // the report names the first offending plane, not just "ragged"
        let (tx, _rx) = mpsc::channel();
        let r = OpRequest::new(
            Op::Add22,
            vec![vec![1.0; 3], vec![1.0; 3], vec![1.0; 2], vec![1.0; 3]],
            tx,
        );
        assert!(matches!(
            r.validate(),
            Err(ServiceError::RaggedPlanes { plane: 2, want: 3, got: 2, .. })
        ));
    }

    #[test]
    fn rejects_zero_length_batches_with_the_specific_variant() {
        let (r, _rx) = req(Op::Add, 2, 0);
        assert_eq!(r.validate().unwrap_err(), ServiceError::EmptyBatch { op: Op::Add });
        let (r, _rx) = req(Op::Split, 1, 0);
        assert!(matches!(r.validate(), Err(ServiceError::EmptyBatch { op: Op::Split })));
    }

    #[test]
    fn arity_is_checked_before_raggedness() {
        // 3 planes for a 4-plane op, one of them ragged: arity wins
        let (tx, _rx) = mpsc::channel();
        let r = OpRequest::new(
            Op::Add22,
            vec![vec![1.0; 4], vec![1.0; 9], vec![1.0; 4]],
            tx,
        );
        assert!(matches!(r.validate(), Err(ServiceError::Arity { .. })));
    }
}
