//! L3 coordinator — the typed, routed, sharded dispatcher over the
//! backend layer.
//!
//! The paper's numbers (Table 3) come from Brook dispatching fragment
//! programs over streams; this module is that runtime's moral
//! equivalent, built the way a 2026 serving stack would. The public
//! surface is typed end to end:
//!
//! * clients name operators with the [`Op`] enum (arity and plane
//!   counts in the type — no string lookup past the parse boundary),
//!   build a [`Plan`] through [`Plan::new`] or the incremental
//!   [`RequestBuilder`] (shapes validated **at build time**, each
//!   failure a specific [`crate::backend::ServiceError`] variant), and
//!   [`Handle::dispatch`] it for a future-like [`Ticket`]
//!   (block, poll, or bounded wait) with real lifecycle control —
//!   [`Ticket::deadline`] and [`Ticket::cancel`] share an atomic
//!   [`TicketState`] with the shard, which skips dead requests
//!   *before* executing them;
//! * a [`ServiceSpec`] describes the shard set **per shard** — e.g.
//!   `[native, native, gpusim:nv35]`, two workhorses plus an
//!   arithmetic-model canary — and a pluggable
//!   [`routing::RoutingPolicy`] routes each request over a live
//!   [`routing::TelemetryView`] of the set (label, queue depth, per-op
//!   capability and measured Melem/s): [`routing::RoundRobin`],
//!   [`routing::QueueDepth`], capability-aware [`routing::OpAffinity`],
//!   telemetry-driven [`routing::Measured`], or a custom policy via
//!   [`Service::start_with_policy`];
//! * N **shard threads** each own one
//!   [`crate::backend::KernelBackend`] instance (native kernels on a
//!   persistent multicore worker crew, the gpusim stream VM, or the
//!   PJRT/XLA engine — the non-`Sync` engines live on the thread that
//!   built them, the exact analogue of a GPU command queue);
//! * each shard runs the **fusion stage**: it coalesces same-operator
//!   requests — holding the batch open for a configurable
//!   [`ServiceSpec::fuse_window`] so cross-client requests land in the
//!   same launch — gathers them into pooled planes
//!   ([`crate::backend::BufferPool`] — no per-batch allocation), packs
//!   them into padded launches over the
//!   [`ServiceSpec::fuse_sizes`] ladder ([`batcher::plan`], with the
//!   tail split across two smaller sizes when that pads less), builds
//!   owned [`crate::backend::ExecJob`]s, executes through the trait,
//!   and slices outputs back per request. On a multi-worker native
//!   shard the gather/scatter copies themselves run in parallel on the
//!   backend's persistent worker crew (bit-identical to the serial
//!   loops), and [`ServiceSpec::numa`] / `FFGPU_NUMA` pins each
//!   shard's crew — and its first-touched staging buffers — to one
//!   NUMA node ([`crate::backend::Topology`]);
//! * [`metrics`] tracks throughput, latency, batch shapes and padding
//!   waste per shard (so heterogeneous sets are observable shard by
//!   shard), merged on read — plus the **telemetry plane**: per-(shard,
//!   op) EWMA throughput/latency/padding-waste cells
//!   ([`metrics::Telemetry`]) written lock-free by the shard threads
//!   and read by measured routing (and future batch-aware planning);
//! * the **accuracy observatory** ([`observatory`]) mirrors a
//!   configurable fraction of live traffic onto a native reference
//!   plus one simulated GPU model per [`ObservatorySpec::models`]
//!   entry, diffs replies lane by lane in ulps, and aggregates
//!   per-(model, op) error statistics the paper only had as static
//!   tables — read them via [`Service::accuracy_report`] or force a
//!   per-request verdict with [`Handle::dispatch_mirrored`]. Mirrored
//!   work runs on the observatory's own backends, so observation never
//!   perturbs routing telemetry or queue depths;
//! * the **result cache** ([`cache`]) content-addresses replies by
//!   bitwise input fingerprint ([`crate::backend::fingerprint`]):
//!   repeated requests resolve from memory before routing, concurrent
//!   identical misses coalesce single-flight behind one execution, and
//!   a byte-budgeted segmented LRU bounds residency — all invisible to
//!   routing telemetry and the observatory sampler;
//! * the **trace recorder/replayer** ([`trace`]) captures live traffic
//!   at the dispatch boundary into a compact versioned binary trace
//!   ([`trace::TraceRecorder`], drop-not-block past a byte budget) and
//!   re-drives any trace deterministically at 1×/N× speed against an
//!   arbitrary shard/routing/fuse/cache configuration
//!   ([`trace::replay`]), producing a [`trace::ReplayReport`] whose
//!   results checksum and verdict counts back the CI replay gate.
//!   Recording, like cache hits and mirrors, is invisible to routing
//!   telemetry and the observatory.
//!
//! The seed's stringly-typed surface — `Handle::submit("add22", ...)`,
//! `Handle::call`, the single-spec `ServiceConfig` — is gone: the last
//! first-party caller migrated in PR 3 and the shims were removed with
//! the pipeline refactor. Parse wire names with
//! [`crate::backend::Op::parse`] and dispatch a [`Plan`].
//!
//! Errors are typed end-to-end ([`crate::backend::ServiceError`]):
//! queue closed, unknown op (parse boundary only), arity mismatch,
//! ragged planes, empty batch, unsupported op, cancelled, deadline
//! exceeded, substrate failure.

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod observatory;
pub mod plan;
pub mod request;
pub mod routing;
pub mod service;
pub mod trace;

pub use crate::backend::Op;
pub use cache::{CacheStats, ResultCache};
pub use metrics::{CacheOpStats, TenantCounters, TenantLedger};
pub use observatory::{
    AccuracyReport, MirrorReport, ModelDiff, ModelReport, ObservatorySpec,
    OpAccuracyRow, TicketSet,
};
pub use plan::{Plan, RequestBuilder, Ticket, TicketState};
pub use request::OpRequest;
pub use crate::backend::{NumaMode, Topology};
pub use routing::{Routing, RoutingPolicy, TelemetryView};
pub use service::{Handle, Service, ServiceSpec, PAPER_FUSE_SIZES};
pub use trace::{
    replay, OpReplayRow, Payload, ReplayReport, ResultChecksum, Trace, TraceError,
    TraceRecord, TraceRecorder, Verdict,
};
