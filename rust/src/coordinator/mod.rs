//! L3 coordinator — the stream dispatcher in front of the PJRT engine.
//!
//! The paper's numbers (Table 3) come from Brook dispatching fragment
//! programs over streams; this module is that runtime's moral
//! equivalent, built the way a 2026 serving stack would:
//!
//! * clients submit [`request::OpRequest`]s (an operator name + SoA
//!   input planes of any length);
//! * the [`batcher`] coalesces same-operator requests and maps them onto
//!   the *fixed* artifact sizes the AOT pipeline compiled (pad to the
//!   next size up, split across launches when larger) — GPU kernels had
//!   fixed-size streams for the same reason;
//! * a dedicated **device thread** owns the (non-`Sync`) PJRT
//!   [`crate::runtime::Runtime`] and drains the queue — the exact
//!   analogue of a GPU command queue;
//! * [`metrics`] tracks throughput, latency, batch shapes and padding
//!   waste.
//!
//! The paper's contribution lives at L1/L2 (the numeric format), so this
//! layer is deliberately thin but real: enough to serve the benchmarks,
//! the examples and the end-to-end driver. A pure-CPU fallback path
//! (`ff::vector::dispatch`) keeps the coordinator usable without
//! artifacts (and provides the Table 4 "CPU path" through the same API).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod service;

pub use request::OpRequest;
pub use service::{Service, ServiceConfig};
