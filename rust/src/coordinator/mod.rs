//! L3 coordinator — the sharded stream dispatcher over the backend layer.
//!
//! The paper's numbers (Table 3) come from Brook dispatching fragment
//! programs over streams; this module is that runtime's moral
//! equivalent, built the way a 2026 serving stack would:
//!
//! * clients submit [`request::OpRequest`]s (an operator name + SoA
//!   input planes of any length) through a round-robin [`service::Handle`];
//! * N **shard threads** each own one [`crate::backend::KernelBackend`]
//!   instance (native multicore kernels, the gpusim stream VM, or the
//!   PJRT/XLA engine — the non-`Sync` engines live on the thread that
//!   built them, the exact analogue of a GPU command queue);
//! * each shard coalesces same-operator requests ([`batcher`]), gathers
//!   them into pooled planes ([`crate::backend::BufferPool`] — no
//!   per-batch allocation), executes through the trait, and scatters
//!   replies; pad-to-compiled-size launch planning lives inside the
//!   XLA backend, where it belongs;
//! * [`metrics`] tracks throughput, latency, batch shapes and padding
//!   waste per shard, merged on read.
//!
//! Errors are typed end-to-end ([`crate::backend::ServiceError`]):
//! queue closed, unknown op, arity/shape mismatch, unsupported op,
//! substrate failure.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod service;

pub use request::OpRequest;
pub use service::{Handle, Service, ServiceConfig};
