//! Minimal arbitrary-precision unsigned integer.
//!
//! Little-endian `u64` limbs, schoolbook algorithms. Sized for the
//! oracle's workload (operands of a few hundred bits); no Karatsuba
//! needed — profile-confirmed off the hot path (§Perf).

use std::cmp::Ordering;

/// Arbitrary-precision unsigned integer, little-endian limbs, no leading
/// zero limbs (canonical form; `0` is the empty limb vector).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BigUint {
    limbs: Vec<u64>,
}

impl BigUint {
    pub const fn zero() -> Self {
        BigUint { limbs: Vec::new() }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 { Self::zero() } else { BigUint { limbs: vec![v] } }
    }

    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        let mut r = BigUint { limbs: vec![lo, hi] };
        r.normalize();
        r
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn limbs(&self) -> &[u64] {
        &self.limbs
    }

    fn normalize(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    /// Number of significant bits (0 for zero).
    pub fn bits(&self) -> u64 {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() as u64 - 1) * 64 + (64 - top.leading_zeros() as u64),
        }
    }

    /// Number of trailing zero bits (0 for zero).
    pub fn trailing_zeros(&self) -> u64 {
        if self.is_zero() {
            return 0;
        }
        let mut tz = 0u64;
        for &l in &self.limbs {
            if l == 0 {
                tz += 64;
            } else {
                return tz + l.trailing_zeros() as u64;
            }
        }
        tz
    }

    /// Bit at position `i` (0 = least significant).
    pub fn bit(&self, i: u64) -> bool {
        let limb = (i / 64) as usize;
        if limb >= self.limbs.len() {
            return false;
        }
        (self.limbs[limb] >> (i % 64)) & 1 == 1
    }

    pub fn add(&self, other: &BigUint) -> BigUint {
        let (long, short) = if self.limbs.len() >= other.limbs.len() {
            (&self.limbs, &other.limbs)
        } else {
            (&other.limbs, &self.limbs)
        };
        let mut out = Vec::with_capacity(long.len() + 1);
        let mut carry = 0u64;
        for i in 0..long.len() {
            let a = long[i];
            let b = short.get(i).copied().unwrap_or(0);
            let (s1, c1) = a.overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            out.push(carry);
        }
        let mut r = BigUint { limbs: out };
        r.normalize();
        r
    }

    /// `self - other`; panics if `other > self`.
    pub fn sub(&self, other: &BigUint) -> BigUint {
        assert!(self.cmp_mag(other) != Ordering::Less, "BigUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let a = self.limbs[i];
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = a.overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        let mut r = BigUint { limbs: out };
        r.normalize();
        r
    }

    pub fn mul(&self, other: &BigUint) -> BigUint {
        if self.is_zero() || other.is_zero() {
            return BigUint::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let t = out[i + j] as u128 + (a as u128) * (b as u128) + carry;
                out[i + j] = t as u64;
                carry = t >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let t = out[k] as u128 + carry;
                out[k] = t as u64;
                carry = t >> 64;
                k += 1;
            }
        }
        let mut r = BigUint { limbs: out };
        r.normalize();
        r
    }

    pub fn shl(&self, n: u64) -> BigUint {
        if self.is_zero() || n == 0 {
            return self.clone();
        }
        let limb_shift = (n / 64) as usize;
        let bit_shift = (n % 64) as u32;
        let mut out = vec![0u64; limb_shift];
        if bit_shift == 0 {
            out.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &l in &self.limbs {
                out.push((l << bit_shift) | carry);
                carry = l >> (64 - bit_shift);
            }
            if carry > 0 {
                out.push(carry);
            }
        }
        let mut r = BigUint { limbs: out };
        r.normalize();
        r
    }

    pub fn shr(&self, n: u64) -> BigUint {
        let limb_shift = (n / 64) as usize;
        if limb_shift >= self.limbs.len() {
            return BigUint::zero();
        }
        let bit_shift = (n % 64) as u32;
        let src = &self.limbs[limb_shift..];
        let mut out = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            out.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let lo = src[i] >> bit_shift;
                let hi = src.get(i + 1).map_or(0, |&x| x << (64 - bit_shift));
                out.push(lo | hi);
            }
        }
        let mut r = BigUint { limbs: out };
        r.normalize();
        r
    }

    pub fn cmp_mag(&self, other: &BigUint) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                ord => return ord,
            }
        }
        Ordering::Equal
    }

    /// Top `n` bits as a u128 (n <= 128), plus a "sticky" flag for any
    /// nonzero bits below. Used for rounding conversions.
    pub fn top_bits(&self, n: u64) -> (u128, bool) {
        let total = self.bits();
        if total == 0 {
            return (0, false);
        }
        if total <= n {
            let mut v = 0u128;
            for (i, &l) in self.limbs.iter().enumerate().take(2) {
                v |= (l as u128) << (64 * i);
            }
            return (v << (n - total).min(127), false);
        }
        let shift = total - n;
        let shifted = self.shr(shift);
        let mut v = 0u128;
        for (i, &l) in shifted.limbs.iter().enumerate().take(2) {
            v |= (l as u128) << (64 * i);
        }
        // sticky: any bit below `shift`?
        let sticky = self.trailing_zeros() < shift;
        (v, sticky)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_roundtrip() {
        let a = BigUint::from_u128(0xFFFF_FFFF_FFFF_FFFF_FFFF);
        let b = BigUint::from_u64(0xABCD);
        let s = a.add(&b);
        assert_eq!(s.sub(&b), a);
        assert_eq!(s.sub(&a), b);
    }

    #[test]
    fn add_carries_across_limbs() {
        let a = BigUint::from_u64(u64::MAX);
        let one = BigUint::from_u64(1);
        let s = a.add(&one);
        assert_eq!(s.limbs(), &[0, 1]);
        assert_eq!(s.bits(), 65);
    }

    #[test]
    fn mul_small_known() {
        let a = BigUint::from_u64(1_000_000_007);
        let b = BigUint::from_u64(998_244_353);
        let p = a.mul(&b);
        assert_eq!(p.limbs(), &[(1_000_000_007u128 * 998_244_353) as u64]);
    }

    #[test]
    fn mul_big_cross_limb() {
        let a = BigUint::from_u128(u128::MAX);
        let p = a.mul(&a);
        // (2^128 - 1)^2 = 2^256 - 2^129 + 1
        assert_eq!(p.bits(), 256);
        assert!(p.bit(0));
        assert!(!p.bit(1));
        assert!(!p.bit(128));
    }

    #[test]
    fn shifts_invert() {
        let a = BigUint::from_u128(0xDEAD_BEEF_0123_4567_89AB_CDEF);
        for n in [0u64, 1, 13, 64, 65, 127, 200] {
            assert_eq!(a.shl(n).shr(n), a, "n={n}");
        }
    }

    #[test]
    fn shr_discards() {
        let a = BigUint::from_u64(0b1011);
        assert_eq!(a.shr(1).limbs(), &[0b101]);
        assert_eq!(a.shr(4).limbs(), &[] as &[u64]);
    }

    #[test]
    fn bits_and_trailing_zeros() {
        assert_eq!(BigUint::zero().bits(), 0);
        assert_eq!(BigUint::from_u64(1).bits(), 1);
        assert_eq!(BigUint::from_u64(0x8000_0000_0000_0000).bits(), 64);
        let a = BigUint::from_u64(0b1100);
        assert_eq!(a.trailing_zeros(), 2);
        let b = BigUint::from_u64(1).shl(130);
        assert_eq!(b.trailing_zeros(), 130);
        assert_eq!(b.bits(), 131);
    }

    #[test]
    fn cmp_mag_orders() {
        let a = BigUint::from_u64(5);
        let b = BigUint::from_u128(1u128 << 100);
        assert_eq!(a.cmp_mag(&b), Ordering::Less);
        assert_eq!(b.cmp_mag(&a), Ordering::Greater);
        assert_eq!(a.cmp_mag(&BigUint::from_u64(5)), Ordering::Equal);
    }

    #[test]
    fn top_bits_with_sticky() {
        // 0b1011_0001: top 4 bits = 1011, sticky = true (0001 below)
        let a = BigUint::from_u64(0b1011_0001);
        let (top, sticky) = a.top_bits(4);
        assert_eq!(top, 0b1011);
        assert!(sticky);
        let b = BigUint::from_u64(0b1011_0000);
        let (top, sticky) = b.top_bits(4);
        assert_eq!(top, 0b1011);
        assert!(!sticky);
    }

    #[test]
    fn mul_matches_u128_randomised() {
        let mut rng = crate::util::Rng::new(61);
        for _ in 0..10_000 {
            let a = rng.next_u64();
            let b = rng.next_u64();
            let p = BigUint::from_u64(a).mul(&BigUint::from_u64(b));
            assert_eq!(p, BigUint::from_u128(a as u128 * b as u128));
        }
    }
}
