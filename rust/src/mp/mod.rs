//! Multiprecision substrate — the accuracy oracle (paper §6.1 used MPFR).
//!
//! The paper measures operator accuracy against MPFR. We have no MPFR in
//! this image, so we build the needed subset from scratch:
//!
//! * [`biguint`] — minimal arbitrary-precision unsigned integer
//!   (schoolbook, little-endian u64 limbs);
//! * [`dyadic`] — **exact** signed dyadic numbers `± m · 2^e`. Every
//!   `f32`/`f64` is a dyadic, and dyadics are closed under `+ - ×`, so
//!   float-float results can be compared against *exact* references with
//!   no oracle error at all (stronger than MPFR at any finite
//!   precision). Division rounds to a requested precision (default 256
//!   bits), which exceeds every bound the paper states by >200 bits.
//!
//! The Table 5 harness ([`crate::harness::accuracy`]) expresses errors in
//! `log2(|err|/|exact|)`, matching the paper's "-48.0" notation.

pub mod biguint;
pub mod dyadic;

pub use biguint::BigUint;
pub use dyadic::Dyadic;
