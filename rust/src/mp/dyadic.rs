//! Exact signed dyadic numbers: `value = sign · mag · 2^exp`.
//!
//! Closed under `+ - ×` with **no rounding whatsoever** — every `f32` and
//! `f64` is exactly representable, so this type is a perfect oracle for
//! float-float accuracy measurement (the role MPFR plays in the paper's
//! §6.1). Division and square root round to a caller-chosen precision.

use super::biguint::BigUint;
use std::cmp::Ordering;

/// An exact dyadic rational `± mag · 2^exp` (canonical: mag odd or zero).
#[derive(Clone, Debug)]
pub struct Dyadic {
    negative: bool,
    mag: BigUint,
    exp: i64,
}

impl Dyadic {
    pub fn zero() -> Self {
        Dyadic { negative: false, mag: BigUint::zero(), exp: 0 }
    }

    pub fn is_zero(&self) -> bool {
        self.mag.is_zero()
    }

    pub fn is_negative(&self) -> bool {
        self.negative
    }

    fn canonical(mut self) -> Self {
        if self.mag.is_zero() {
            self.negative = false;
            self.exp = 0;
            return self;
        }
        let tz = self.mag.trailing_zeros();
        if tz > 0 {
            self.mag = self.mag.shr(tz);
            self.exp += tz as i64;
        }
        self
    }

    pub fn from_parts(negative: bool, mag: BigUint, exp: i64) -> Self {
        Dyadic { negative, mag, exp }.canonical()
    }

    /// Exact conversion from `f32` (panics on NaN/Inf: the paper excludes
    /// specials from accuracy runs).
    pub fn from_f32(v: f32) -> Self {
        assert!(v.is_finite(), "Dyadic::from_f32 on non-finite {v}");
        Self::from_f64(v as f64)
    }

    /// Exact conversion from `f64`.
    pub fn from_f64(v: f64) -> Self {
        assert!(v.is_finite(), "Dyadic::from_f64 on non-finite {v}");
        if v == 0.0 {
            return Self::zero();
        }
        let bits = v.to_bits();
        let negative = bits >> 63 == 1;
        let biased = ((bits >> 52) & 0x7FF) as i64;
        let frac = bits & 0xF_FFFF_FFFF_FFFF;
        let (mant, exp) = if biased == 0 {
            (frac, -1074i64) // subnormal
        } else {
            (frac | (1 << 52), biased - 1075)
        };
        Self::from_parts(negative, BigUint::from_u64(mant), exp)
    }

    /// Exact value of a float-float pair `hi + lo`.
    pub fn from_ff(hi: f32, lo: f32) -> Self {
        Self::from_f32(hi).add(&Self::from_f32(lo))
    }

    pub fn neg(&self) -> Self {
        if self.is_zero() {
            return self.clone();
        }
        Dyadic { negative: !self.negative, mag: self.mag.clone(), exp: self.exp }
    }

    pub fn abs(&self) -> Self {
        Dyadic { negative: false, mag: self.mag.clone(), exp: self.exp }
    }

    /// Exact addition.
    pub fn add(&self, other: &Dyadic) -> Self {
        if self.is_zero() {
            return other.clone();
        }
        if other.is_zero() {
            return self.clone();
        }
        // align to the smaller exponent
        let exp = self.exp.min(other.exp);
        let a = self.mag.shl((self.exp - exp) as u64);
        let b = other.mag.shl((other.exp - exp) as u64);
        if self.negative == other.negative {
            return Dyadic { negative: self.negative, mag: a.add(&b), exp }.canonical();
        }
        match a.cmp_mag(&b) {
            Ordering::Equal => Self::zero(),
            Ordering::Greater => {
                Dyadic { negative: self.negative, mag: a.sub(&b), exp }.canonical()
            }
            Ordering::Less => {
                Dyadic { negative: other.negative, mag: b.sub(&a), exp }.canonical()
            }
        }
    }

    /// Exact subtraction.
    pub fn sub(&self, other: &Dyadic) -> Self {
        self.add(&other.neg())
    }

    /// Exact multiplication.
    pub fn mul(&self, other: &Dyadic) -> Self {
        if self.is_zero() || other.is_zero() {
            return Self::zero();
        }
        Dyadic {
            negative: self.negative != other.negative,
            mag: self.mag.mul(&other.mag),
            exp: self.exp + other.exp,
        }
        .canonical()
    }

    /// Division correctly rounded (to nearest, ties away) to `prec` bits
    /// of significand.
    pub fn div(&self, other: &Dyadic, prec: u64) -> Self {
        assert!(!other.is_zero(), "Dyadic division by zero");
        if self.is_zero() {
            return Self::zero();
        }
        // scale numerator so the integer quotient has >= prec+1 bits
        let shift = prec + 2 + other.mag.bits();
        let num = self.mag.shl(shift);
        let (q, r) = div_rem(&num, &other.mag);
        // round to nearest on the remainder: q += (2r >= d)
        let q = {
            let twice = r.shl(1);
            if twice.cmp_mag(&other.mag) != Ordering::Less {
                q.add(&BigUint::from_u64(1))
            } else {
                q
            }
        };
        Dyadic {
            negative: self.negative != other.negative,
            mag: q,
            exp: self.exp - other.exp - shift as i64,
        }
        .canonical()
    }

    pub fn cmp(&self, other: &Dyadic) -> Ordering {
        match (self.is_zero(), other.is_zero()) {
            (true, true) => return Ordering::Equal,
            (true, false) => {
                return if other.negative { Ordering::Greater } else { Ordering::Less }
            }
            (false, true) => {
                return if self.negative { Ordering::Less } else { Ordering::Greater }
            }
            _ => {}
        }
        if self.negative != other.negative {
            return if self.negative { Ordering::Less } else { Ordering::Greater };
        }
        let mag_ord = self.cmp_mag_aligned(other);
        if self.negative { mag_ord.reverse() } else { mag_ord }
    }

    fn cmp_mag_aligned(&self, other: &Dyadic) -> Ordering {
        // compare |self| vs |other|: compare bit-lengths + exponents first
        let hb_a = self.exp + self.mag.bits() as i64;
        let hb_b = other.exp + other.mag.bits() as i64;
        if hb_a != hb_b {
            return hb_a.cmp(&hb_b);
        }
        let exp = self.exp.min(other.exp);
        let a = self.mag.shl((self.exp - exp) as u64);
        let b = other.mag.shl((other.exp - exp) as u64);
        a.cmp_mag(&b)
    }

    /// Round to nearest `f64` (ties to even). Saturates to ±inf outside
    /// range (not expected in our workloads).
    pub fn to_f64(&self) -> f64 {
        if self.is_zero() {
            return 0.0;
        }
        let bits = self.mag.bits();
        let (top54, sticky) = self.mag.top_bits(54);
        // top54 holds the leading 54 bits; we want 53 with G/S rounding
        let mant = (top54 >> 1) as u64;
        let guard = top54 & 1 == 1;
        let sticky = sticky || (bits > 54 && self.mag.trailing_zeros() < bits - 54);
        let mut m = mant; // 53 bits (top bit set)
        if guard && (sticky || m & 1 == 1) {
            m += 1;
        }
        let e2 = self.exp + bits as i64 - 53; // exponent of bit 0 of m
        // m may have carried to 54 bits; f64 multiply absorbs that.
        // Split the scale in two so subnormal results stay representable
        // (a single pow2() step would underflow to zero prematurely).
        let mant_f = m as f64;
        let h1 = e2 / 2;
        let h2 = e2 - h1;
        let val = (mant_f * pow2(h1)) * pow2(h2);
        if self.negative { -val } else { val }
    }

    /// Round to nearest `f32`.
    pub fn to_f32(&self) -> f32 {
        self.to_f64() as f32 // double rounding safe: 53 - 24 > 2 guard bits
    }

    /// `log2(|self|)` approximately (for error reporting).
    pub fn log2_abs(&self) -> f64 {
        if self.is_zero() {
            return f64::NEG_INFINITY;
        }
        let bits = self.mag.bits();
        let (top, _) = self.mag.top_bits(53);
        let frac = top as f64 / 2f64.powi(52); // in [1, 2)
        (self.exp + bits as i64 - 1) as f64 + frac.log2()
    }
}

/// `2^e` as f64, handling the full dyadic exponent range by stepping.
fn pow2(e: i64) -> f64 {
    if (-1022..=1023).contains(&e) {
        return f64::from_bits(((e + 1023) as u64) << 52);
    }
    // subnormal / huge: build by squaring steps (rare path)
    let mut r = 1.0f64;
    let step = if e > 0 { 512 } else { -512 };
    let mut left = e;
    while left != 0 {
        let s = if left.abs() >= 512 { step } else { left };
        r *= f64::from_bits(((s + 1023) as u64) << 52);
        left -= s;
    }
    r
}

/// Schoolbook long division: returns (quotient, remainder).
fn div_rem(num: &BigUint, den: &BigUint) -> (BigUint, BigUint) {
    assert!(!den.is_zero());
    if num.cmp_mag(den) == Ordering::Less {
        return (BigUint::zero(), num.clone());
    }
    let shift = num.bits() - den.bits();
    let mut rem = num.clone();
    let mut quo = BigUint::zero();
    let mut d = den.shl(shift);
    let one = BigUint::from_u64(1);
    for i in (0..=shift).rev() {
        if rem.cmp_mag(&d) != Ordering::Less {
            rem = rem.sub(&d);
            quo = quo.add(&one.shl(i));
        }
        d = d.shr(1);
    }
    (quo, rem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn f64_roundtrip_exact() {
        let mut rng = Rng::new(71);
        for _ in 0..50_000 {
            let v = rng.normal() * rng.uniform(-300.0, 300.0).exp2();
            if !v.is_finite() || v == 0.0 {
                continue;
            }
            assert_eq!(Dyadic::from_f64(v).to_f64(), v, "v={v}");
        }
    }

    #[test]
    fn f32_roundtrip_exact() {
        let mut rng = Rng::new(72);
        for _ in 0..50_000 {
            let v = rng.spread_f32(-120, 120);
            assert_eq!(Dyadic::from_f32(v).to_f32(), v, "v={v}");
        }
    }

    #[test]
    fn subnormal_f64_roundtrip() {
        for v in [f64::MIN_POSITIVE / 2.0, 5e-324, -5e-324, f64::MIN_POSITIVE] {
            assert_eq!(Dyadic::from_f64(v).to_f64(), v, "v={v:e}");
        }
    }

    #[test]
    fn add_is_exact_vs_f64_where_f64_is_exact() {
        // sums of f32s fit f64 exactly
        let mut rng = Rng::new(73);
        for _ in 0..50_000 {
            let a = rng.spread_f32(-20, 20);
            let b = rng.spread_f32(-20, 20);
            let d = Dyadic::from_f32(a).add(&Dyadic::from_f32(b));
            assert_eq!(d.to_f64(), a as f64 + b as f64);
        }
    }

    #[test]
    fn mul_is_exact_vs_f64_where_f64_is_exact() {
        let mut rng = Rng::new(74);
        for _ in 0..50_000 {
            let a = rng.spread_f32(-20, 20);
            let b = rng.spread_f32(-20, 20);
            let d = Dyadic::from_f32(a).mul(&Dyadic::from_f32(b));
            assert_eq!(d.to_f64(), a as f64 * b as f64);
        }
    }

    #[test]
    fn add_exactness_beyond_f64() {
        // 1 + 2^-200 - 1 == 2^-200 exactly
        let one = Dyadic::from_f64(1.0);
        let tiny = Dyadic::from_parts(false, BigUint::from_u64(1), -200);
        let r = one.add(&tiny).sub(&one);
        assert_eq!(r.cmp(&tiny), Ordering::Equal);
    }

    #[test]
    fn sub_cancellation_to_zero() {
        let a = Dyadic::from_f64(3.5);
        assert!(a.sub(&a).is_zero());
    }

    #[test]
    fn signs_and_cmp() {
        let a = Dyadic::from_f64(-2.0);
        let b = Dyadic::from_f64(1.0);
        assert_eq!(a.cmp(&b), Ordering::Less);
        assert_eq!(b.cmp(&a), Ordering::Greater);
        assert_eq!(a.cmp(&a.clone()), Ordering::Equal);
        assert_eq!(a.neg().cmp(&Dyadic::from_f64(2.0)), Ordering::Equal);
        assert_eq!(a.abs().cmp(&Dyadic::from_f64(2.0)), Ordering::Equal);
        assert!(Dyadic::zero().cmp(&b) == Ordering::Less);
        assert!(Dyadic::zero().cmp(&a) == Ordering::Greater);
    }

    #[test]
    fn div_matches_f64_to_53_bits() {
        let mut rng = Rng::new(75);
        for _ in 0..20_000 {
            let a = rng.normal();
            let b = rng.normal();
            if b.abs() < 1e-3 {
                continue;
            }
            let q = Dyadic::from_f64(a).div(&Dyadic::from_f64(b), 64);
            let rel = ((q.to_f64() - a / b) / (a / b)).abs();
            assert!(rel <= 2f64.powi(-52), "a={a} b={b} rel={rel:e}");
        }
    }

    #[test]
    fn div_high_precision_residual_small() {
        let a = Dyadic::from_f64(1.0);
        let b = Dyadic::from_f64(3.0);
        let q = a.div(&b, 256);
        // |1 - 3q| <= 3 * 2^-256-ish
        let resid = a.sub(&q.mul(&b)).abs();
        let bound = Dyadic::from_parts(false, BigUint::from_u64(1), -250);
        assert_eq!(resid.cmp(&bound), Ordering::Less);
    }

    #[test]
    fn from_ff_is_exact_sum() {
        let hi = 1.5f32;
        let lo = 2f32.powi(-30);
        let d = Dyadic::from_ff(hi, lo);
        assert_eq!(d.to_f64(), hi as f64 + lo as f64);
    }

    #[test]
    fn log2_abs_sane() {
        assert!((Dyadic::from_f64(8.0).log2_abs() - 3.0).abs() < 1e-12);
        assert!((Dyadic::from_f64(0.25).log2_abs() + 2.0).abs() < 1e-12);
        let v = Dyadic::from_parts(false, BigUint::from_u64(3), -100);
        assert!((v.log2_abs() - (3f64.log2() - 100.0)).abs() < 1e-9);
    }

    #[test]
    fn ties_to_even_rounding() {
        // 2^53 + 1 is a tie between 2^53 and 2^53+2 -> rounds to even 2^53
        let v = Dyadic::from_parts(false, BigUint::from_u64((1 << 53) + 1), 0);
        assert_eq!(v.to_f64(), 9007199254740992.0);
        // 2^53 + 3 -> rounds to 2^53 + 4
        let v = Dyadic::from_parts(false, BigUint::from_u64((1 << 53) + 3), 0);
        assert_eq!(v.to_f64(), 9007199254740996.0);
    }
}
