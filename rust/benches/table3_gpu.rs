//! Bench: paper Table 3 — float-float operators on the "GPU" (XLA/PJRT)
//! path, normalised to the single-precision Add at 4096 elements.
//!
//! `cargo bench --bench table3_gpu` prints the measured grid next to the
//! paper's, plus the derived shape checks the harness tracks
//! (Add12 ≈ Add; Add22/Mul22 within a small multiple of Add; cost growth
//! with size far flatter than the CPU path's).
//!
//! No criterion in the vendored set: benches are plain `main()`s with
//! the shared [`ffgpu::util::Timer`] protocol (warmup + median).

use ffgpu::harness::{timing, workload};
use ffgpu::runtime::Runtime;
use ffgpu::util::Timer;
use std::path::PathBuf;

fn main() {
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("table3_gpu: {e} — run `make artifacts` first");
            return;
        }
    };
    let timer = Timer::new(3, 9);
    println!("platform: {}", rt.platform());
    let grid = timing::gpu_grid(&rt, &workload::PAPER_SIZES, &workload::PAPER_OPS,
                                &timer, 0x7AB3)
        .expect("gpu grid");
    print!("{}", grid.render("Table 3 (measured) — XLA/PJRT path, normalised to Add@4096"));

    // raw seconds for the record
    println!("\nraw median seconds:");
    for (si, &n) in grid.sizes.iter().enumerate() {
        let row: Vec<String> = grid.seconds[si].iter().map(|s| format!("{s:.3e}")).collect();
        println!("  n={n:>8}: {}", row.join("  "));
    }

    // paper reference + shape checks
    let (_, paper) = timing::paper_table3();
    println!("\npaper Table 3 (7800GTX, 2006):");
    for (s, r) in workload::PAPER_SIZES.iter().zip(&paper) {
        let cells: String = r.iter().map(|v| format!("{v:>7.2}")).collect();
        println!("  n={s:>8}: {cells}");
    }

    let norm = grid.normalised();
    let col = |op: &str| grid.ops.iter().position(|o| o == op).unwrap();
    let shape_checks = [
        ("Add12 ~ Add at 4096 (paper 1.09x)",
         norm[0][col("add12")] / norm[0][col("add")], 0.5, 4.0),
        ("Add22 / Add at 4096 (paper 1.55x)",
         norm[0][col("add22")] / norm[0][col("add")], 0.8, 8.0),
        ("Mul22 / Add at 4096 (paper 1.54x)",
         norm[0][col("mul22")] / norm[0][col("add")], 0.8, 8.0),
        ("Add growth 4096->1M (paper 10.6x)",
         norm[4][col("add")] / norm[0][col("add")], 2.0, 300.0),
    ];
    println!("\nshape checks:");
    for (name, v, lo, hi) in shape_checks {
        let ok = v >= lo && v <= hi;
        println!("  [{}] {name}: {v:.2} (accept {lo}..{hi})",
                 if ok { "ok" } else { "!!" });
    }
}
