//! Bench: paper Table 5 — measured accuracy sweep (2^24 vectors per op
//! by default, like the paper; override with FFGPU_SAMPLES).
//!
//! Three executors: native CPU kernels, XLA artifacts, and the simulated
//! NV35 GPU — the last reproduces the paper's measured rows (its -48.0
//! Add12 anomaly comes from truncated-with-guard addition, not from the
//! algorithms).

use ffgpu::coordinator::batcher::op_arity;
use ffgpu::gpusim::{algorithms as sim, GpuModel};
use ffgpu::harness::accuracy;
use ffgpu::runtime::Runtime;
use std::path::PathBuf;
use std::time::Instant;

fn main() {
    let samples: usize = std::env::var("FFGPU_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 24);
    let ops = ["add12", "mul12", "add22", "mul22"];
    println!("Table 5 sweep: {samples} samples per op\n");

    let t0 = Instant::now();
    println!("native CPU kernels (IEEE RN):");
    for op in ops {
        let row = accuracy::measure_op(op, samples, 1 << 16, 0x7AB5, |op, planes| {
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let (_, n_out) = op_arity(op).unwrap();
            let mut outs = vec![vec![0.0f32; planes[0].len()]; n_out];
            ffgpu::ff::vector::dispatch(op, &refs, &mut outs)?;
            Ok(outs)
        })
        .unwrap();
        println!("  {:<6} {}", row.op, row.display());
    }
    println!("  ({:.1}s)", t0.elapsed().as_secs_f64());

    // XLA path at a reduced sample count (PJRT dispatch dominates)
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if let Ok(rt) = Runtime::new(&artifacts) {
        let xs = samples.min(1 << 20);
        println!("\nXLA artifacts via PJRT ({xs} samples):");
        for op in ops {
            let row = accuracy::measure_op(op, xs, 65536, 0x7AB6, |op, planes| {
                let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
                rt.execute(&format!("{op}_n65536"), &refs)
            })
            .unwrap();
            println!("  {:<6} {}", row.op, row.display());
        }
    }

    // simulated NV35 (scalar soft-float: reduced count)
    let gs = samples.min(1 << 16);
    println!("\nsimulated NV35 GPU arithmetic ({gs} samples):");
    let m = GpuModel::NV35;
    for op in ops {
        let row = accuracy::measure_op(op, gs, 1 << 12, 0x7AB7, |op, planes| {
            let n = planes[0].len();
            let mut outs = vec![vec![0.0f32; n]; 2];
            for i in 0..n {
                let q = |p: usize| m.quantize(planes[p][i] as f64);
                let (h, l) = match op {
                    "add12" => sim::add12(&m, q(0), q(1)),
                    "mul12" => sim::mul12(&m, q(0), q(1)),
                    "add22" => sim::add22(&m, (q(0), q(1)), (q(2), q(3))),
                    "mul22" => sim::mul22(&m, (q(0), q(1)), (q(2), q(3))),
                    other => return Err(format!("no sim for {other}")),
                };
                outs[0][i] = m.to_f64(h) as f32;
                outs[1][i] = m.to_f64(l) as f32;
            }
            Ok(outs)
        })
        .unwrap();
        println!("  {:<6} {}", row.op, row.display());
    }

    println!("\npaper Table 5 (2006 hardware):");
    for (op, v) in accuracy::paper_table5() {
        println!("  {op:<6} {v}");
    }
}
