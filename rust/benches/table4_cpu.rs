//! Bench: paper Table 4 — float-float operators on the CPU path
//! (native rust kernels), normalised to Add at 4096.
//!
//! Reproduces the paper's CPU protocol including the *branchy* Add22
//! (their CPU library variant whose test "breaks the execution
//! pipeline"). Shape checks: Add22-branchy costs the most among the ff
//! ops; CPU small-to-large growth far exceeds the GPU path's.
//!
//! The grid runs on the kernel tier this host resolves to
//! (`FFGPU_KERNEL_TIER` > CPU detection), and every row is labelled
//! with it so numbers from different machines/builds stay
//! attributable. `FFGPU_KERNEL_TIER=scalar` recovers the paper-era
//! scalar protocol exactly.

use ffgpu::backend::KernelTier;
use ffgpu::harness::{timing, workload};
use ffgpu::util::Timer;

fn main() {
    let tier = KernelTier::resolve(None);
    let timer = Timer::new(3, 9);
    let grid = timing::cpu_grid_tier(&workload::PAPER_SIZES, &workload::PAPER_OPS,
                                     &timer, 0x7AB4, tier);
    print!("{}", grid.render(&format!(
        "Table 4 (measured) — native CPU path, kernel tier '{tier}', \
         normalised to Add@4096")));

    println!("\nraw median seconds (tier {tier}):");
    for (si, &n) in grid.sizes.iter().enumerate() {
        let row: Vec<String> = grid.seconds[si].iter().map(|s| format!("{s:.3e}")).collect();
        println!("  n={n:>8} [{tier}]: {}", row.join("  "));
    }

    let (_, paper) = timing::paper_table4();
    println!("\npaper Table 4 (Pentium IV HT 3.2GHz, 2006):");
    for (s, r) in workload::PAPER_SIZES.iter().zip(&paper) {
        let cells: String = r.iter().map(|v| format!("{v:>8.2}")).collect();
        println!("  n={s:>8}: {cells}");
    }

    let norm = grid.normalised();
    let col = |op: &str| grid.ops.iter().position(|o| o == op).unwrap();
    let ff_cost_1m = norm[4][col("mul22")] / norm[4][col("mul")];
    let add22_vs_mul22 = norm[4][col("add22")] / norm[4][col("mul22")];
    let growth = norm[4][col("add")] / norm[0][col("add")];
    println!("\nshape checks:");
    println!("  [{}] Mul22/Mul at 1M (paper ~4.1x): {ff_cost_1m:.2} (accept 2..12)",
             if (2.0..12.0).contains(&ff_cost_1m) { "ok" } else { "!!" });
    // blocked tiers speed up mul22 but add22 stays the branchy scalar
    // protocol, so the upper bound leaves room for the tier gap
    println!("  [{}] branchy Add22 vs Mul22 at 1M (paper 2.8x): {add22_vs_mul22:.2} (accept 0.8..16)",
             if (0.8..16.0).contains(&add22_vs_mul22) { "ok" } else { "!!" });
    println!("  [{}] Add growth 4096->1M (paper 270x incl. cache effects): {growth:.1} (accept 100..3000)",
             if (100.0..3000.0).contains(&growth) { "ok" } else { "!!" });
}
