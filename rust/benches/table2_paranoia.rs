//! Bench: paper Table 2 — paranoia error-interval measurement over the
//! simulated GPU models (plus measurement throughput, since the sweep
//! itself is a workload).

use ffgpu::harness::paranoia_table;
use std::time::Instant;

fn main() {
    let samples = 500_000;
    let t0 = Instant::now();
    let table = paranoia_table::measure(samples, 0x7AB2);
    let secs = t0.elapsed().as_secs_f64();
    print!("{}", table.render());
    println!(
        "\nmeasurement: {} probes x 4 models x 4 ops in {secs:.2}s ({:.1}M op-evals/s)",
        samples,
        (samples as f64 * 16.0) / secs / 1e6
    );
}
