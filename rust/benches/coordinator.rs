//! Bench: coordinator throughput/latency — the L3 hot path.
//!
//! Not a paper table (the paper has no serving layer); this is the §Perf
//! instrument for L3: requests/s and per-batch latency across request
//! sizes and client counts, on both backends.

use ffgpu::coordinator::service::Backend;
use ffgpu::coordinator::{Service, ServiceConfig};
use ffgpu::harness::workload;
use ffgpu::util::Rng;
use std::path::PathBuf;
use std::time::Instant;

fn bench_backend(name: &str, backend: Backend) {
    println!("== backend: {name}");
    for (clients, req_n, rounds) in
        [(1usize, 4096usize, 200usize), (4, 4096, 100), (8, 1000, 100), (4, 100_000, 20)]
    {
        let svc = Service::start(ServiceConfig {
            backend: backend.clone(),
            max_batch: 64,
            precompile: false,
        })
        .expect("service");
        // warmup (compiles artifacts on first touch)
        let h = svc.handle();
        let planes = workload::planes_for("add22", req_n, 1);
        h.call("add22", planes).unwrap();

        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64);
                for _ in 0..rounds {
                    let planes = workload::planes_for("add22", req_n, rng.next_u64());
                    h.call("add22", planes).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = svc.metrics();
        let total_req = (clients * rounds) as f64;
        let total_elems = total_req * req_n as f64;
        println!(
            "  {clients} clients x {req_n:>6} elems: {:>8.0} req/s  {:>7.1} Melem/s  \
             batches={:<5} pad={:>4.1}%  lat mean={:.2}ms",
            total_req / wall,
            total_elems / wall / 1e6,
            m.batches,
            m.padding_fraction() * 100.0,
            m.mean_latency_s * 1e3,
        );
    }
}

fn main() {
    bench_backend("cpu (native kernels)", Backend::Cpu);
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if artifacts.join("manifest.json").exists() {
        bench_backend("xla (PJRT artifacts)", Backend::Xla(artifacts));
    } else {
        println!("(skipping xla backend: no artifacts)");
    }
}
