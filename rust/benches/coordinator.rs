//! Bench: coordinator throughput/latency across backends, shard
//! counts and routing policies — the L3 hot path.
//!
//! Not a paper table (the paper has no serving layer); this is the
//! §Perf instrument for the backend layer: requests/s, Melem/s and
//! client-side p50/p95 dispatch latency for native single-shard (the
//! seed's serving behaviour), native sharded, the gpusim stream VM,
//! XLA when artifacts exist, and a routing-policy comparison
//! (round-robin vs queue-depth vs op-affinity vs telemetry-driven
//! measured) over a heterogeneous native+gpusim shard set. For the
//! heterogeneous cases the bench also records each shard's observed
//! Melem/s and the **canary share** — the fraction of slow-op
//! (`mul22`/`div22`) traffic the gpusim canary received — so routing
//! *quality*, not just throughput, is machine-readable across PRs in
//! `BENCH_coordinator.json`. The run asserts that measured routing
//! sends strictly less slow-op traffic to the canary than round-robin,
//! and that a 1 ms-deadline ticket against a saturated shard resolves
//! `DeadlineExceeded` promptly while the shard survives.
//!
//! Pipeline instrumentation (the persistent-worker + fusion refactor):
//! small-batch (≤ 16k element) native execute throughput is recorded
//! for the **pre-refactor spawn-per-batch scoped pool** (kept here,
//! and only here, as a baseline) against the **persistent worker
//! crew**; serving rows compare **fused vs unfused** coalescing on
//! tiny concurrent requests; and the routing-policy sweep runs again
//! with the fusion ladder armed so `BENCH_coordinator.json` carries a
//! padding-waste fraction per policy.
//!
//! Accuracy instrumentation (the observatory): a mirrored canary
//! stream over `nv35`/`r300`/`chopped` produces the live Table-2/5
//! report (written to `TABLE2_LIVE.txt`, uploaded as a CI artifact)
//! and an `accuracy` section of per-(model, op) min/max/mean ulp error
//! and max log2 relative error in `BENCH_coordinator.json`.
//!
//! Kernel-tier instrumentation (the SIMD/FMA tier engine): every
//! available tier (scalar / blocked / blocked-fma) is swept per op at
//! single-worker, chunk > n — a pure kernel measurement with no
//! crew/queue overhead — and recorded as the `kernel_tiers` section of
//! `BENCH_coordinator.json`, so per-tier Melem/s is machine-comparable
//! across PRs and build flavours. The blocked-vs-scalar mul22 ratio is
//! printed as an `[ok]`/`[!!]` shape check (not asserted: shared CI
//! hosts are too noisy for a hard perf gate).
//!
//! Wire instrumentation (the TCP front end): the same workload runs
//! in-process and through a loopback [`ffgpu::net::WireServer`] — the
//! p50/p95 gap is the transport tax — and an over-quota bulk client
//! runs against a tightened token bucket to record the pushback rate;
//! both land in the `wire` section of `BENCH_coordinator.json`.
//!
//! Cache instrumentation (the content-addressed result cache): the
//! same concurrent workload runs twice against a single-worker shard —
//! once with every grid distinct (all misses, every request executes)
//! and once with every grid drawn from a primed repeated set (all
//! hits, no request touches the shard) — at 65536 and 1048576 lanes;
//! warm-vs-cold req/s and p50/p95 land in the `cache` section of
//! `BENCH_coordinator.json`, with warm/cold ≥ 10x printed as an
//! `[ok]`/`[!!]` shape check at 1M lanes. The same section carries the
//! waste-fed fuse-ladder comparison: an awkwardly-sized request stream
//! over the static ladder vs `adaptive_ladder`, whose padding-waste
//! gap is asserted (the EWMA trigger is deterministic).
//!
//! Data-path instrumentation (the NUMA-aware data path): a fused tiny-
//! request workload runs against a multi-worker shard (gather/scatter
//! staged on the persistent crew) and against the `workers = 1`
//! degenerate case (the serial loops, kept as the baseline); each
//! shard's EWMA gather/execute/scatter wall split lands in the
//! `data_path` section of `BENCH_coordinator.json`. The same sharded
//! workload then runs with `NumaMode::Auto` (topology-pinned crews and
//! first-touch arenas) vs `NumaMode::Off`; req/s and p50/p95 land in
//! the `numa` section together with a `single_node` label from
//! [`Topology::detect`], so cross-PR comparisons know when the host
//! could not express locality at all.

#[path = "../tests/common/mod.rs"]
mod common;

use common::WorkloadGen;
use ffgpu::backend::{
    BackendSpec, ExecJob, KernelBackend, KernelTier, NativeBackend, Op, ServiceError,
};
use ffgpu::coordinator::{
    replay, NumaMode, ObservatorySpec, Plan, Routing, Service, ServiceSpec, Topology,
    Trace,
};
use ffgpu::ff::vector;
use ffgpu::net::{
    AdmissionConfig, ClassLimits, ClientClass, WireClient, WireConfig, WireError,
    WireServer,
};
use ffgpu::util::Rng;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

struct Row {
    backend: String,
    shards: usize,
    routing: String,
    clients: usize,
    req_n: usize,
    rounds: usize,
    req_per_s: f64,
    melem_per_s: f64,
    batches: u64,
    padding_fraction: f64,
    mean_latency_ms: f64,
    p50_ms: f64,
    p95_ms: f64,
    /// Observed throughput per shard over the measured phase.
    shard_melem_per_s: Vec<f64>,
    /// Fraction of mul22/div22 requests the gpusim canary served
    /// (heterogeneous cases only).
    canary_share: Option<f64>,
    /// Fusion window armed on the service (0 = fusion off).
    fuse_window_ms: u64,
}

/// One `accuracy` row of `BENCH_coordinator.json`: the live
/// observatory's per-(model, op) error surface over the bench's
/// mirrored canary stream.
struct AccRow {
    model: String,
    op: String,
    lanes: u64,
    min_ulp: f64,
    max_ulp: f64,
    mean_abs_ulp: f64,
    max_rel_log2: Option<f64>,
}

/// One `kernel_tiers` row of `BENCH_coordinator.json`: single-worker
/// native kernel throughput for one (tier, op, batch size) cell.
struct TierRow {
    tier: &'static str,
    op: &'static str,
    n: usize,
    melem_per_s: f64,
}

/// One `wire` row of `BENCH_coordinator.json`: the TCP front end's
/// transport overhead (loopback vs in-process over the same service)
/// and pushback behaviour under deliberate overload.
struct WireRow {
    path: &'static str,
    clients: usize,
    req_n: usize,
    rounds: usize,
    req_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    completed: u64,
    overloaded: u64,
}

/// One `cache` row of `BENCH_coordinator.json`: the result cache's
/// warm-vs-cold serving surface (`cache-cold` / `cache-warm`
/// scenarios) and the waste-fed fuse-ladder comparison
/// (`ladder-static` / `ladder-adaptive`, where `padding_fraction` is
/// the payload and the hit/miss counters stay zero).
struct CacheRow {
    scenario: &'static str,
    req_n: usize,
    clients: usize,
    rounds: usize,
    req_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    hits: u64,
    misses: u64,
    padding_fraction: f64,
}

/// One `data_path` row of `BENCH_coordinator.json`: a shard's EWMA
/// gather/execute/scatter wall-time split over a fused workload —
/// staged parallel copies on the persistent crew vs the `workers = 1`
/// serial baseline.
struct DataPathRow {
    mode: &'static str,
    workers: usize,
    req_n: usize,
    gather_ms: f64,
    execute_ms: f64,
    scatter_ms: f64,
}

/// One `replay` row of `BENCH_coordinator.json`: the committed golden
/// trace re-driven against one serving configuration. The results
/// checksum is asserted identical across configurations — routing,
/// fusion and caching may move latency, never bits.
struct ReplayBenchRow {
    config: &'static str,
    records: usize,
    rate: f64,
    wall_s: f64,
    padding_waste: f64,
    cache_hit_rate: f64,
    results_fnv: u64,
    p95_ms_max: f64,
}

/// The `numa` section of `BENCH_coordinator.json`: pinned-vs-unpinned
/// rows plus the host's topology verdict.
struct NumaSection {
    /// `true` when [`Topology::detect`] saw one node — the pinned run
    /// was then unpinned by construction, not a measurement.
    single_node: bool,
    rows: Vec<NumaRow>,
}

/// One `numa` row of `BENCH_coordinator.json`: sharded serving with
/// topology pinning on (`auto`) vs off, same workload and shard shape.
struct NumaRow {
    mode: &'static str,
    shards: usize,
    req_n: usize,
    req_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    /// Node id each shard landed on (`null` = unpinned).
    nodes: Vec<Option<usize>>,
}

/// Ops the routing comparison cycles through. Includes `div22` — the
/// op the paper's Table 4 shows widest apart across substrates — so
/// the canary-share metric covers the expensive tail (the bench does
/// not compare answers across substrates, only placement and timing).
const MIX_OPS: [Op; 5] = [Op::Add22, Op::Mul22, Op::Div22, Op::Mul12, Op::Add12];

/// Slow ops the canary-share metric tracks.
const SLOW_OPS: [Op; 2] = [Op::Mul22, Op::Div22];

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

fn run_case(
    label: &str, spec: ServiceSpec, clients: usize, req_n: usize, rounds: usize,
    mixed_ops: bool,
) -> Option<Row> {
    let shards = spec.shards.len();
    let routing = spec.routing;
    let fuse_window_ms = spec.fuse_window.as_millis() as u64;
    let svc = match Service::start(spec) {
        Ok(s) => s,
        Err(e) => {
            println!("  (skipping {label} x{shards}: {e})");
            return None;
        }
    };
    // warmup every shard (touch each one explicitly via its own op mix),
    // then let the shard threads finish recording their latency samples
    // before snapshotting: metrics for a batch land *after* its reply,
    // so an immediate snapshot would race and charge warmup cost to the
    // measured phase
    let wl = WorkloadGen::from_env(label);
    let h = svc.handle();
    for i in 0..shards.max(1) * 2 {
        let op = if mixed_ops { MIX_OPS[i % MIX_OPS.len()] } else { Op::Add22 };
        let planes = wl.planes(op, req_n, 1 + i as u64);
        h.dispatch(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
    }
    std::thread::sleep(Duration::from_millis(50));
    let warm = svc.metrics();
    let warm_shards = svc.shard_metrics();

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            // (op, shard the policy picked, dispatch->reply seconds)
            let mut log: Vec<(Op, usize, f64)> = Vec::with_capacity(rounds);
            for round in 0..rounds {
                let op = if mixed_ops {
                    MIX_OPS[(c + round) % MIX_OPS.len()]
                } else {
                    Op::Add22
                };
                let planes = wl.planes(op, req_n, rng.next_u64());
                let t = Instant::now();
                let ticket = h.dispatch(Plan::new(op, planes).unwrap()).unwrap();
                let shard = ticket.shard();
                ticket.wait().unwrap();
                log.push((op, shard, t.elapsed().as_secs_f64()));
            }
            log
        }));
    }
    let mut log: Vec<(Op, usize, f64)> = Vec::new();
    for j in joins {
        log.extend(j.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    // same settle as the warmup snapshot: the final batch's latency
    // sample lands after its reply, so don't snapshot under the race
    std::thread::sleep(Duration::from_millis(50));
    let m = svc.metrics();
    let shard_m = svc.shard_metrics();
    let total_req = (clients * rounds) as f64;
    let total_elems = total_req * req_n as f64;
    // measured-phase deltas (warmup excluded)
    let batches = m.batches - warm.batches;
    let elements = m.elements - warm.elements;
    let padded = m.padded_elements - warm.padded_elements;
    let lat_count = m.latency_count - warm.latency_count;
    let mean_latency_s = if lat_count > 0 {
        (m.mean_latency_s * m.latency_count as f64
            - warm.mean_latency_s * warm.latency_count as f64)
            / lat_count as f64
    } else {
        0.0
    };
    let padding_fraction = if elements + padded > 0 {
        padded as f64 / (elements + padded) as f64
    } else {
        0.0
    };
    let mut lats: Vec<f64> = log.iter().map(|&(_, _, l)| l).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let shard_melem_per_s: Vec<f64> = shard_m
        .iter()
        .zip(&warm_shards)
        .map(|(after, before)| (after.elements - before.elements) as f64 / wall / 1e6)
        .collect();
    // canary share: slow-op requests that landed on a gpusim shard
    let labels = svc.shard_labels();
    let canary_share = if labels.iter().any(|&l| l == "gpusim") && mixed_ops {
        let slow_total =
            log.iter().filter(|(op, _, _)| SLOW_OPS.contains(op)).count();
        let slow_on_canary = log
            .iter()
            .filter(|&&(op, shard, _)| {
                SLOW_OPS.contains(&op) && labels[shard] == "gpusim"
            })
            .count();
        if slow_total > 0 {
            Some(slow_on_canary as f64 / slow_total as f64)
        } else {
            None
        }
    } else {
        None
    };
    let row = Row {
        backend: label.to_string(),
        shards,
        routing: routing.name().to_string(),
        clients,
        req_n,
        rounds,
        req_per_s: total_req / wall,
        melem_per_s: total_elems / wall / 1e6,
        batches,
        padding_fraction,
        mean_latency_ms: mean_latency_s * 1e3,
        p50_ms: percentile(&lats, 0.50) * 1e3,
        p95_ms: percentile(&lats, 0.95) * 1e3,
        shard_melem_per_s,
        canary_share,
        fuse_window_ms,
    };
    println!(
        "  {label:<16} shards={shards} routing={:<11} {clients} clients x {req_n:>6} elems: \
         {:>8.0} req/s  {:>7.1} Melem/s  batches={:<5} pad={:>4.1}%  \
         lat mean={:.2}ms p50={:.2}ms p95={:.2}ms{}",
        row.routing,
        row.req_per_s,
        row.melem_per_s,
        row.batches,
        row.padding_fraction * 100.0,
        row.mean_latency_ms,
        row.p50_ms,
        row.p95_ms,
        match row.canary_share {
            Some(s) => format!("  canary-share={:.0}%", s * 100.0),
            None => String::new(),
        },
    );
    Some(row)
}

/// The accuracy observatory as a bench instrument: mirror a canary
/// stream over the paper's three non-IEEE models, render the live
/// Table-2/Table-5 report (uploaded as a CI artifact next to the
/// JSON), and return the per-(model, op) rows for the `accuracy`
/// section of `BENCH_coordinator.json`.
fn observatory_rows() -> Vec<AccRow> {
    println!("== accuracy observatory: live Table-2/5 sweep (nv35 / r300 / chopped)");
    let svc = Service::start(
        ServiceSpec::uniform(BackendSpec::native_single(), 1)
            .with_observatory(ObservatorySpec::new(1.0, ["nv35", "r300", "chopped"])),
    )
    .unwrap();
    let wl = WorkloadGen::from_env("observatory");
    let h = svc.handle();
    let ops = [Op::Add12, Op::Mul12, Op::Add22, Op::Mul22];
    for op in ops {
        for round in 0..4u64 {
            let planes = wl.planes(op, 2048, 0xACC + round);
            h.dispatch(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
        }
    }
    let rep = svc.accuracy_report().expect("observatory armed");
    // observation rode beside serving: the shards saw exactly the
    // client's requests, nothing mirrored leaked in
    assert_eq!(svc.metrics().requests, (ops.len() * 4) as u64);
    assert_eq!(rep.mirrored_requests, (ops.len() * 4) as u64);
    let t2 = rep.render_table2_live();
    let t5 = rep.render_table5_live();
    print!("{t2}");
    match std::fs::write("TABLE2_LIVE.txt", format!("{t2}\n{t5}")) {
        Ok(()) => println!("wrote TABLE2_LIVE.txt"),
        Err(e) => println!("could not write TABLE2_LIVE.txt: {e}"),
    }
    rep.models
        .iter()
        .flat_map(|m| {
            m.rows.iter().map(move |r| AccRow {
                model: m.model.clone(),
                op: r.op.name().to_string(),
                lanes: r.lanes,
                min_ulp: r.min_ulp,
                max_ulp: r.max_ulp,
                mean_abs_ulp: r.mean_abs_ulp,
                max_rel_log2: r.max_rel_log2(),
            })
        })
        .collect()
}

#[allow(clippy::too_many_arguments)] // one sink, one section per instrument
fn emit_json(
    rows: &[Row], tiers: &[TierRow], accuracy: &[AccRow], wire: &[WireRow],
    cache: &[CacheRow], data_path: &[DataPathRow], numa: &NumaSection,
    replay_rows: &[ReplayBenchRow],
) {
    let mut out = String::from(
        "{\n  \"bench\": \"coordinator\",\n  \"unit\": {\"req_per_s\": \"requests/s\", \
         \"melem_per_s\": \"1e6 elements/s\", \"canary_share\": \
         \"fraction of mul22/div22 requests served by the gpusim canary\"},\n  \
         \"results\": [\n",
    );
    for (i, r) in rows.iter().enumerate() {
        let shard_rates: Vec<String> =
            r.shard_melem_per_s.iter().map(|v| format!("{v:.3}")).collect();
        let canary = match r.canary_share {
            Some(s) => format!("{s:.4}"),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"backend\": \"{}\", \"shards\": {}, \"routing\": \"{}\", \
             \"clients\": {}, \"req_n\": {}, \"rounds\": {}, \"req_per_s\": {:.1}, \
             \"melem_per_s\": {:.3}, \"batches\": {}, \
             \"padding_fraction\": {:.4}, \"mean_latency_ms\": {:.3}, \
             \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
             \"shard_melem_per_s\": [{}], \"canary_share\": {}, \
             \"fuse_window_ms\": {}}}{}\n",
            r.backend,
            r.shards,
            r.routing,
            r.clients,
            r.req_n,
            r.rounds,
            r.req_per_s,
            r.melem_per_s,
            r.batches,
            r.padding_fraction,
            r.mean_latency_ms,
            r.p50_ms,
            r.p95_ms,
            shard_rates.join(", "),
            canary,
            r.fuse_window_ms,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    // per-tier per-op single-worker kernel throughput (the SIMD/FMA
    // tier engine's acceptance surface)
    out.push_str(&format!(
        "  ],\n  \"detected_tier\": \"{}\",\n  \"kernel_tiers\": [\n",
        KernelTier::detect()
    ));
    for (i, t) in tiers.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"tier\": \"{}\", \"op\": \"{}\", \"n\": {}, \
             \"melem_per_s\": {:.3}}}{}\n",
            t.tier,
            t.op,
            t.n,
            t.melem_per_s,
            if i + 1 < tiers.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n  \"accuracy\": [\n");
    for (i, a) in accuracy.iter().enumerate() {
        let rel = match a.max_rel_log2 {
            Some(v) => format!("{v:.2}"),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"model\": \"{}\", \"op\": \"{}\", \"lanes\": {}, \
             \"min_ulp\": {:.4}, \"max_ulp\": {:.4}, \"mean_abs_ulp\": {:.6}, \
             \"max_rel_log2\": {}}}{}\n",
            a.model,
            a.op,
            a.lanes,
            a.min_ulp,
            a.max_ulp,
            a.mean_abs_ulp,
            rel,
            if i + 1 < accuracy.len() { "," } else { "" },
        ));
    }
    // the TCP front end: transport overhead + pushback behaviour
    out.push_str("  ],\n  \"wire\": [\n");
    for (i, w) in wire.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"path\": \"{}\", \"clients\": {}, \"req_n\": {}, \"rounds\": {}, \
             \"req_per_s\": {:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
             \"completed\": {}, \"overloaded\": {}}}{}\n",
            w.path,
            w.clients,
            w.req_n,
            w.rounds,
            w.req_per_s,
            w.p50_ms,
            w.p95_ms,
            w.completed,
            w.overloaded,
            if i + 1 < wire.len() { "," } else { "" },
        ));
    }
    // the result cache + waste-fed planning: warm-vs-cold serving and
    // static-vs-adaptive ladder padding waste
    out.push_str("  ],\n  \"cache\": [\n");
    for (i, c) in cache.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"req_n\": {}, \"clients\": {}, \
             \"rounds\": {}, \"req_per_s\": {:.1}, \"p50_ms\": {:.3}, \
             \"p95_ms\": {:.3}, \"hits\": {}, \"misses\": {}, \
             \"padding_fraction\": {:.4}}}{}\n",
            c.scenario,
            c.req_n,
            c.clients,
            c.rounds,
            c.req_per_s,
            c.p50_ms,
            c.p95_ms,
            c.hits,
            c.misses,
            c.padding_fraction,
            if i + 1 < cache.len() { "," } else { "" },
        ));
    }
    // the NUMA-aware data path: per-group gather/execute/scatter wall
    // split, staged crew vs the workers=1 serial baseline
    out.push_str("  ],\n  \"data_path\": [\n");
    for (i, d) in data_path.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"workers\": {}, \"req_n\": {}, \
             \"gather_ms\": {:.4}, \"execute_ms\": {:.4}, \"scatter_ms\": {:.4}}}{}\n",
            d.mode,
            d.workers,
            d.req_n,
            d.gather_ms,
            d.execute_ms,
            d.scatter_ms,
            if i + 1 < data_path.len() { "," } else { "" },
        ));
    }
    // topology pinning on vs off over the same sharded workload; on a
    // single-node host the "auto" run is unpinned by construction
    out.push_str(&format!(
        "  ],\n  \"numa\": {{\n    \"single_node\": {},\n    \"rows\": [\n",
        numa.single_node
    ));
    for (i, r) in numa.rows.iter().enumerate() {
        let cells: Vec<String> = r
            .nodes
            .iter()
            .map(|n| match n {
                Some(id) => id.to_string(),
                None => "null".to_string(),
            })
            .collect();
        out.push_str(&format!(
            "      {{\"mode\": \"{}\", \"shards\": {}, \"req_n\": {}, \
             \"req_per_s\": {:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
             \"nodes\": [{}]}}{}\n",
            r.mode,
            r.shards,
            r.req_n,
            r.req_per_s,
            r.p50_ms,
            r.p95_ms,
            cells.join(", "),
            if i + 1 < numa.rows.len() { "," } else { "" },
        ));
    }
    // the golden trace re-driven per serving configuration: a fixed
    // recorded workload, so routing/fuse/cache quality is comparable
    // across PRs without synthetic-load noise
    out.push_str("    ]\n  },\n  \"replay\": [\n");
    for (i, r) in replay_rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"config\": \"{}\", \"records\": {}, \"rate\": {:.1}, \
             \"wall_s\": {:.4}, \"padding_waste\": {:.4}, \"cache_hit_rate\": {:.4}, \
             \"p95_ms_max\": {:.3}, \"results_fnv\": \"{:#018x}\"}}{}\n",
            r.config,
            r.records,
            r.rate,
            r.wall_s,
            r.padding_waste,
            r.cache_hit_rate,
            r.p95_ms_max,
            r.results_fnv,
            if i + 1 < replay_rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    let path = "BENCH_coordinator.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!(
            "\nwrote {path} ({} rows, {} tier cells, {} accuracy cells, {} wire rows, \
             {} cache rows, {} data-path rows, {} numa rows, {} replay rows)",
            rows.len(),
            tiers.len(),
            accuracy.len(),
            wire.len(),
            cache.len(),
            data_path.len(),
            numa.rows.len(),
            replay_rows.len()
        ),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}

/// The pre-refactor executor, kept **only as a bench baseline**: a
/// scoped worker pool spawned and joined inside every call — the
/// spawn/join overhead the persistent crew removed from the serving
/// hot path. Chunking logic mirrors the old `NativeBackend::execute`.
fn scoped_pool_execute(
    op: Op, chunk: usize, workers: usize, inputs: &[&[f32]], outputs: &mut [Vec<f32>],
) {
    struct Job<'a> {
        ins: Vec<&'a [f32]>,
        outs: Vec<&'a mut [f32]>,
    }
    let n = inputs[0].len();
    let mut jobs: Vec<Job> = Vec::with_capacity(n.div_ceil(chunk));
    let mut tails: Vec<&mut [f32]> =
        outputs.iter_mut().map(|v| v.as_mut_slice()).collect();
    let mut start = 0usize;
    while start < n {
        let len = chunk.min(n - start);
        let ins: Vec<&[f32]> = inputs.iter().map(|p| &p[start..start + len]).collect();
        let mut outs = Vec::with_capacity(tails.len());
        for t in tails.iter_mut() {
            let (head, rest) = std::mem::take(t).split_at_mut(len);
            outs.push(head);
            *t = rest;
        }
        jobs.push(Job { ins, outs });
        start += len;
    }
    let workers = workers.min(jobs.len());
    let queue = Mutex::new(jobs);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                let Some(mut job) = job else { break };
                vector::dispatch_slices(op.name(), &job.ins, &mut job.outs).unwrap();
            });
        }
    });
}

/// Acceptance instrument: small-batch (≤ 16k element) native execute
/// throughput, spawn-per-batch scoped pool vs the persistent crew.
/// The smaller the batch, the larger the share of its wall time the
/// old spawn/join burned — exactly what the persistent workers buy
/// back.
fn exec_rows() -> Vec<Row> {
    println!("== native execute ≤16k: scoped spawn-per-batch baseline vs persistent crew");
    let (op, chunk, workers, reps) = (Op::Add22, 2048usize, 4usize, 400usize);
    let wl = WorkloadGen::from_env("exec_rows");
    let mut rows = Vec::new();
    for req_n in [4096usize, 8192, 16384] {
        let planes = wl.planes(op, req_n, 0xE8EC);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let job = ExecJob::new(op, planes.clone()).unwrap();
        let mut outs = vec![vec![0.0f32; req_n]; op.n_out()];

        let mut crew = NativeBackend::new(chunk, workers);
        for _ in 0..10 {
            crew.execute(&job, &mut outs).unwrap();
        }
        let t0 = Instant::now();
        for _ in 0..reps {
            crew.execute(&job, &mut outs).unwrap();
        }
        let persistent_s = t0.elapsed().as_secs_f64();

        for _ in 0..10 {
            scoped_pool_execute(op, chunk, workers, &refs, &mut outs);
        }
        let t0 = Instant::now();
        for _ in 0..reps {
            scoped_pool_execute(op, chunk, workers, &refs, &mut outs);
        }
        let scoped_s = t0.elapsed().as_secs_f64();

        let total_elems = (reps * req_n) as f64;
        for (label, secs) in
            [("native-exec-persistent", persistent_s), ("native-exec-scoped", scoped_s)]
        {
            let melem = total_elems / secs / 1e6;
            println!(
                "  {label:<22} n={req_n:>6} x{reps}: {melem:>8.1} Melem/s \
                 ({:.1} µs/batch)",
                secs / reps as f64 * 1e6
            );
            rows.push(Row {
                backend: label.to_string(),
                shards: 1,
                routing: "-".to_string(),
                clients: 1,
                req_n,
                rounds: reps,
                req_per_s: reps as f64 / secs,
                melem_per_s: melem,
                batches: reps as u64,
                padding_fraction: 0.0,
                mean_latency_ms: secs / reps as f64 * 1e3,
                p50_ms: 0.0,
                p95_ms: 0.0,
                shard_melem_per_s: vec![melem],
                canary_share: None,
                fuse_window_ms: 0,
            });
        }
    }
    rows
}

/// SIMD/FMA tier instrument: sweep every available kernel tier over
/// the ff op set at single-worker with chunk > n, so the measured loop
/// is the kernel itself — no chunk queueing, no crew handoff. Feeds
/// the `kernel_tiers` section of `BENCH_coordinator.json`.
fn kernel_tier_rows() -> Vec<TierRow> {
    println!("== kernel tiers: single-worker native Melem/s per (tier, op)");
    println!(
        "  detected tier: {} (fast FMA: {})",
        KernelTier::detect(),
        ffgpu::ff::simd::fma_available()
    );
    let ops = [Op::Add22, Op::Mul22, Op::Mul12, Op::Div22, Op::Mad22];
    let sizes = [65_536usize, 1_048_576];
    let wl = WorkloadGen::from_env("kernel_tiers");
    let mut rows = Vec::new();
    for tier in KernelTier::ALL {
        if !tier.available() {
            println!("  (skipping tier {tier}: not fast on this host/build)");
            continue;
        }
        // chunk 1 << 22 > every n: the whole batch runs serially in
        // one kernel call on the requesting thread
        let mut be = NativeBackend::with_tier(1 << 22, 1, Some(tier));
        for &n in &sizes {
            for op in ops {
                let planes = wl.planes(op, n, 0x71E2);
                let job = ExecJob::new(op, planes).unwrap();
                let mut outs = vec![vec![0.0f32; n]; op.n_out()];
                be.execute(&job, &mut outs).unwrap(); // warmup
                let reps = if n >= 1_000_000 { 5 } else { 30 };
                let mut best = f64::INFINITY;
                for _ in 0..reps {
                    let t0 = Instant::now();
                    be.execute(&job, &mut outs).unwrap();
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                let melem = n as f64 / best / 1e6;
                println!("  {:<12} {:<6} n={n:>8}: {melem:>8.1} Melem/s",
                         tier.name(), op.name());
                rows.push(TierRow { tier: tier.name(), op: op.name(), n, melem_per_s: melem });
            }
        }
    }
    // acceptance shape: the blocked tier should not lose to scalar on
    // mul22 at large batches; printed, not asserted (shared CI hosts
    // are too noisy for a hard perf gate)
    for &n in &sizes {
        let rate = |t: &str| {
            rows.iter()
                .find(|r| r.tier == t && r.op == "mul22" && r.n == n)
                .map(|r| r.melem_per_s)
        };
        if let (Some(s), Some(b)) = (rate("scalar"), rate("blocked")) {
            println!(
                "  [{}] blocked/scalar mul22 @ {n}: {:.2}x",
                if b >= s { "ok" } else { "!!" },
                b / s
            );
        }
    }
    rows
}

/// Wire front end instrument: the same `add22` workload dispatched
/// in-process and over loopback TCP against the same service shape
/// (per-request transport overhead), then a deliberately over-quota
/// bulk client against a tightened token bucket (pushback rate —
/// denied submits never reach the shards, so refusals stay cheap).
/// Feeds the `wire` section of `BENCH_coordinator.json`.
fn wire_rows() -> Vec<WireRow> {
    println!("== wire front end: loopback TCP vs in-process, and token-bucket pushback");
    let (clients, req_n, rounds) = (4usize, 4096usize, 50usize);
    let wl = WorkloadGen::from_env("wire_rows");
    let mut rows = Vec::new();

    let svc = Service::start(ServiceSpec::uniform(BackendSpec::native(), 2)).unwrap();
    let srv =
        WireServer::start(svc.handle(), "127.0.0.1:0", WireConfig::default()).unwrap();
    let addr = srv.local_addr().to_string();

    // in-process baseline: the same service, no transport
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xB135 + c as u64);
            let mut lats = Vec::with_capacity(rounds);
            for _ in 0..rounds {
                let planes = wl.planes(Op::Add22, req_n, rng.next_u64());
                let t = Instant::now();
                h.dispatch(Plan::new(Op::Add22, planes).unwrap())
                    .unwrap()
                    .wait()
                    .unwrap();
                lats.push(t.elapsed().as_secs_f64());
            }
            lats
        }));
    }
    let mut lats: Vec<f64> =
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rows.push(WireRow {
        path: "in-process",
        clients,
        req_n,
        rounds,
        req_per_s: (clients * rounds) as f64 / wall,
        p50_ms: percentile(&lats, 0.50) * 1e3,
        p95_ms: percentile(&lats, 0.95) * 1e3,
        completed: (clients * rounds) as u64,
        overloaded: 0,
    });

    // the same workload through the TCP front end on loopback
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let tenant = format!("bench-{c}");
            let mut cli =
                WireClient::connect(&addr, &tenant, ClientClass::Standard).unwrap();
            cli.set_io_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut rng = Rng::new(0xC135 + c as u64);
            let mut lats = Vec::with_capacity(rounds);
            for _ in 0..rounds {
                let planes = wl.planes(Op::Add22, req_n, rng.next_u64());
                let t = Instant::now();
                cli.call(Op::Add22, planes, None).unwrap();
                lats.push(t.elapsed().as_secs_f64());
            }
            lats
        }));
    }
    let mut lats: Vec<f64> =
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rows.push(WireRow {
        path: "wire-loopback",
        clients,
        req_n,
        rounds,
        req_per_s: (clients * rounds) as f64 / wall,
        p50_ms: percentile(&lats, 0.50) * 1e3,
        p95_ms: percentile(&lats, 0.95) * 1e3,
        completed: (clients * rounds) as u64,
        overloaded: 0,
    });
    srv.shutdown();
    drop(svc);

    // pushback under overload: one bulk client far past a tightened
    // bucket — denials must appear and admitted work must still finish
    let svc = Service::start(ServiceSpec::uniform(BackendSpec::native(), 2)).unwrap();
    let admission = AdmissionConfig::default().with_limits(
        ClientClass::Bulk,
        ClassLimits {
            lanes_per_sec: 50_000.0,
            burst_lanes: 100_000.0,
            max_inflight_bytes: 64 << 20,
        },
    );
    let srv = WireServer::start(
        svc.handle(),
        "127.0.0.1:0",
        WireConfig { admission, ..WireConfig::default() },
    )
    .unwrap();
    let addr = srv.local_addr().to_string();
    let (hog_rounds, hog_n) = (40usize, 16_384usize);
    let mut cli = WireClient::connect(&addr, "bench-hog", ClientClass::Bulk).unwrap();
    cli.set_io_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rng = Rng::new(0xD135);
    let (mut done, mut pushed) = (0u64, 0u64);
    let mut lats = Vec::new();
    let t0 = Instant::now();
    for _ in 0..hog_rounds {
        let planes = wl.planes(Op::Add22, hog_n, rng.next_u64());
        let t = Instant::now();
        match cli.call(Op::Add22, planes, None) {
            Ok(_) => {
                done += 1;
                lats.push(t.elapsed().as_secs_f64());
            }
            Err(WireError::Overloaded { .. }) => pushed += 1,
            Err(e) => panic!("wire bench hog: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rows.push(WireRow {
        path: "wire-overload",
        clients: 1,
        req_n: hog_n,
        rounds: hog_rounds,
        req_per_s: hog_rounds as f64 / wall,
        p50_ms: percentile(&lats, 0.50) * 1e3,
        p95_ms: percentile(&lats, 0.95) * 1e3,
        completed: done,
        overloaded: pushed,
    });
    assert!(pushed > 0, "over-quota bulk client must be pushed back");
    assert!(done > 0, "pushback must shape the hog, not starve it");
    srv.shutdown();
    drop(svc);

    for r in &rows {
        println!(
            "  {:<14} {} clients x {:>6} elems x {:>3}: {:>7.0} verdicts/s  \
             p50={:.2}ms p95={:.2}ms  completed={} overloaded={}",
            r.path, r.clients, r.req_n, r.rounds, r.req_per_s, r.p50_ms, r.p95_ms,
            r.completed, r.overloaded,
        );
    }
    rows
}

/// Ops the cache instrument cycles through — `div22` keeps the
/// expensive tail in the mix so the cold phase pays real compute.
const CACHE_OPS: [Op; 3] = [Op::Add22, Op::Mul22, Op::Div22];

/// One measured phase of the cache instrument: `clients` concurrent
/// threads, `rounds` dispatches each, cycling [`CACHE_OPS`]. With
/// `warm_seed` set every thread draws from the same fixed grid per op
/// (repeats → hits); without it every grid is distinct (→ misses).
fn cache_phase(
    svc: &Service, wl: WorkloadGen, clients: usize, rounds: usize, req_n: usize,
    warm_seed: Option<u64>,
) -> (Vec<f64>, f64) {
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xCAC4E + c as u64);
            let mut lats = Vec::with_capacity(rounds);
            for round in 0..rounds {
                let op = CACHE_OPS[(c + round) % CACHE_OPS.len()];
                let case = warm_seed.unwrap_or_else(|| rng.next_u64());
                let planes = wl.planes(op, req_n, case);
                let t = Instant::now();
                h.dispatch(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
                lats.push(t.elapsed().as_secs_f64());
            }
            lats
        }));
    }
    let mut lats: Vec<f64> =
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lats, wall)
}

/// Result-cache instrument: the same concurrent workload against a
/// single-worker shard, cold (every grid distinct — every request
/// executes, serialized on the one worker) vs warm (every grid from a
/// primed repeated set — every request resolves at the cache, in
/// parallel, without touching the shard). The warm phase's hit count
/// is exact and asserted: nothing inserts between priming and the
/// phase, so nothing can evict the primed entries.
fn cache_rows() -> Vec<CacheRow> {
    println!("== result cache: cold distinct grids vs warm repeated grids (single-worker shard)");
    let mut rows = Vec::new();
    let clients = 4usize;
    let wl = WorkloadGen::from_env("cache_rows");
    for (req_n, rounds) in [(65_536usize, 40usize), (1_048_576, 8)] {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1).with_cache_mb(512),
        )
        .unwrap();
        let h = svc.handle();
        // shard warmup (crew spin-up, page faults) — one distinct grid
        h.dispatch(Plan::new(Op::Div22, wl.planes(Op::Div22, req_n, 0xFEED)).unwrap())
            .unwrap()
            .wait()
            .unwrap();

        let base = svc.cache_stats().unwrap();
        let (cold_lats, cold_wall) = cache_phase(&svc, wl, clients, rounds, req_n, None);
        let after_cold = svc.cache_stats().unwrap();
        let cold = CacheRow {
            scenario: "cache-cold",
            req_n,
            clients,
            rounds,
            req_per_s: (clients * rounds) as f64 / cold_wall,
            p50_ms: percentile(&cold_lats, 0.50) * 1e3,
            p95_ms: percentile(&cold_lats, 0.95) * 1e3,
            hits: after_cold.hits - base.hits,
            misses: after_cold.misses - base.misses,
            padding_fraction: 0.0,
        };

        // prime one grid per op, then measure pure repeats
        for op in CACHE_OPS {
            let planes = wl.planes(op, req_n, 0x5EED);
            h.dispatch(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
        }
        let primed = svc.cache_stats().unwrap();
        let (warm_lats, warm_wall) =
            cache_phase(&svc, wl, clients, rounds, req_n, Some(0x5EED));
        let after_warm = svc.cache_stats().unwrap();
        let warm = CacheRow {
            scenario: "cache-warm",
            req_n,
            clients,
            rounds,
            req_per_s: (clients * rounds) as f64 / warm_wall,
            p50_ms: percentile(&warm_lats, 0.50) * 1e3,
            p95_ms: percentile(&warm_lats, 0.95) * 1e3,
            hits: after_warm.hits - primed.hits,
            misses: after_warm.misses - primed.misses,
            padding_fraction: 0.0,
        };
        assert_eq!(
            warm.hits,
            (clients * rounds) as u64,
            "warm phase over primed grids must be all hits"
        );
        for r in [&cold, &warm] {
            println!(
                "  {:<12} {clients} clients x {req_n:>8} elems x {rounds:>3}: \
                 {:>8.0} req/s  p50={:.2}ms p95={:.2}ms  hits={} misses={}",
                r.scenario, r.req_per_s, r.p50_ms, r.p95_ms, r.hits, r.misses,
            );
        }
        // acceptance shape: repeated grids must serve an order of
        // magnitude faster warm than cold; printed, not asserted
        // (shared CI hosts are too noisy for a hard perf gate)
        if req_n >= 1_000_000 {
            println!(
                "  [{}] warm/cold req/s @ {req_n}: {:.1}x",
                if warm.req_per_s >= 10.0 * cold.req_per_s { "ok" } else { "!!" },
                warm.req_per_s / cold.req_per_s
            );
        }
        rows.push(cold);
        rows.push(warm);
    }
    rows
}

/// Waste-fed planning instrument: a stream of awkwardly-sized requests
/// (6000 lanes against a 1024/4096/16384/65536 ladder) served with the
/// static ladder vs `adaptive_ladder`. The first batch tail-splits to
/// 4096+4096 (26.8% waste) either way and seeds the waste EWMA hot
/// (past the 15% threshold); from the second batch the adaptive ladder
/// densifies and plans 2560+4096 (9.9% waste). The gap is
/// deterministic, so it's asserted.
fn ladder_rows() -> Vec<CacheRow> {
    println!("== fuse ladder: static vs waste-fed adaptive (6000-lane add22 stream)");
    let (req_n, rounds) = (6000usize, 40usize);
    let wl = WorkloadGen::from_env("ladder_rows");
    let mut rows = Vec::new();
    let mut pfs = Vec::new();
    for (adaptive, scenario) in [(false, "ladder-static"), (true, "ladder-adaptive")] {
        let mut spec = ServiceSpec::uniform(BackendSpec::native(), 1)
            .with_fuse_window(Duration::from_millis(1))
            .with_fuse_sizes(vec![1024, 4096, 16384, 65536]);
        if adaptive {
            spec = spec.with_adaptive_ladder(true);
        }
        let svc = Service::start(spec).unwrap();
        let h = svc.handle();
        let mut rng = Rng::new(0x1ADE);
        let mut lats = Vec::with_capacity(rounds);
        let t0 = Instant::now();
        for _ in 0..rounds {
            let planes = wl.planes(Op::Add22, req_n, rng.next_u64());
            let t = Instant::now();
            h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap().wait().unwrap();
            lats.push(t.elapsed().as_secs_f64());
        }
        let wall = t0.elapsed().as_secs_f64();
        // metrics for a batch land after its reply — settle first
        std::thread::sleep(Duration::from_millis(50));
        let pf = svc.metrics().padding_fraction();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "  {scenario:<16} {rounds} x {req_n} elems: pad={:>5.1}%  {:>6.0} req/s",
            pf * 100.0,
            rounds as f64 / wall
        );
        pfs.push(pf);
        rows.push(CacheRow {
            scenario,
            req_n,
            clients: 1,
            rounds,
            req_per_s: rounds as f64 / wall,
            p50_ms: percentile(&lats, 0.50) * 1e3,
            p95_ms: percentile(&lats, 0.95) * 1e3,
            hits: 0,
            misses: 0,
            padding_fraction: pf,
        });
    }
    assert!(
        pfs[1] < pfs[0],
        "adaptive ladder must waste less padding than static: adaptive={:.3} vs \
         static={:.3}",
        pfs[1],
        pfs[0]
    );
    rows
}

/// Data-path instrument: the same fused tiny-request stream against a
/// 4-worker shard (gather/scatter staged on the persistent crew) and
/// the `workers = 1` degenerate case (the serial loops). The shard's
/// [`Service::shard_stage_split`] EWMA — recorded per fused group —
/// is the payload; the split shows how much of a group's wall time the
/// data path (copies) costs relative to the kernels.
fn data_path_rows() -> Vec<DataPathRow> {
    println!("== data path: gather/execute/scatter split (staged crew vs serial workers=1)");
    let (clients, req_n, rounds) = (4usize, 2048usize, 30usize);
    let wl = WorkloadGen::from_env("data_path_rows");
    let mut rows = Vec::new();
    for (mode, workers) in [("staged", 4usize), ("serial", 1)] {
        let spec = ServiceSpec::uniform(
            BackendSpec::Native { chunk: 4096, workers, tier: None, node: None },
            1,
        )
        .with_max_batch(64)
        .with_fuse_window(Duration::from_millis(1))
        .with_fuse_sizes(vec![1024, 4096, 16384, 65536]);
        let svc = Service::start(spec).unwrap();
        let mut joins = Vec::new();
        for c in 0..clients {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xDA7A + c as u64);
                for round in 0..rounds {
                    let op = MIX_OPS[(c + round) % MIX_OPS.len()];
                    let planes = wl.planes(op, req_n, rng.next_u64());
                    h.dispatch(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // the split for a group lands after its replies — settle first
        std::thread::sleep(Duration::from_millis(50));
        let (g, e, s) = svc
            .shard_stage_split(0)
            .expect("fused groups must record a stage split");
        let row = DataPathRow {
            mode,
            workers,
            req_n,
            gather_ms: g * 1e3,
            execute_ms: e * 1e3,
            scatter_ms: s * 1e3,
        };
        println!(
            "  {:<8} workers={} {clients} clients x {req_n:>5} elems x {rounds}: \
             gather={:.3}ms execute={:.3}ms scatter={:.3}ms per group",
            row.mode, row.workers, row.gather_ms, row.execute_ms, row.scatter_ms,
        );
        rows.push(row);
    }
    rows
}

/// NUMA instrument: the same sharded `add22` workload with topology
/// pinning on (`auto` — crews and first-touch arenas land node-local)
/// vs off (the scheduler floats threads freely). On a single-node or
/// containerized host the pinned run degrades to unpinned — the
/// `single_node` label in the JSON says so, and the comparison is then
/// a no-op by construction rather than a measurement.
fn numa_rows() -> NumaSection {
    let single_node = Topology::detect().is_single_node();
    println!(
        "== numa: pinned (auto) vs unpinned (off), 2 native shards{}",
        if single_node { "  [single-node host: pinning is a no-op]" } else { "" }
    );
    let (clients, req_n, rounds) = (4usize, 65_536usize, 30usize);
    let wl = WorkloadGen::from_env("numa_rows");
    let mut rows = Vec::new();
    for (mode, label) in [(NumaMode::Auto, "auto"), (NumaMode::Off, "off")] {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native(), 2).with_numa(mode),
        )
        .unwrap();
        let h = svc.handle();
        // warmup: touch both shards, fault the arenas in
        for i in 0..4u64 {
            h.dispatch(Plan::new(Op::Add22, wl.planes(Op::Add22, req_n, 1 + i)).unwrap())
                .unwrap()
                .wait()
                .unwrap();
        }
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0x40DE + c as u64);
                let mut lats = Vec::with_capacity(rounds);
                for _ in 0..rounds {
                    let planes = wl.planes(Op::Add22, req_n, rng.next_u64());
                    let t = Instant::now();
                    h.dispatch(Plan::new(Op::Add22, planes).unwrap())
                        .unwrap()
                        .wait()
                        .unwrap();
                    lats.push(t.elapsed().as_secs_f64());
                }
                lats
            }));
        }
        let mut lats: Vec<f64> =
            joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let nodes = svc.shard_numa_nodes();
        let row = NumaRow {
            mode: label,
            shards: nodes.len(),
            req_n,
            req_per_s: (clients * rounds) as f64 / wall,
            p50_ms: percentile(&lats, 0.50) * 1e3,
            p95_ms: percentile(&lats, 0.95) * 1e3,
            nodes,
        };
        let cells: Vec<String> = row
            .nodes
            .iter()
            .map(|n| match n {
                Some(id) => format!("node{id}"),
                None => "-".to_string(),
            })
            .collect();
        println!(
            "  {:<5} {clients} clients x {req_n:>6} elems x {rounds}: {:>7.0} req/s  \
             p50={:.2}ms p95={:.2}ms  shards=[{}]",
            row.mode, row.req_per_s, row.p50_ms, row.p95_ms, cells.join(", "),
        );
        rows.push(row);
    }
    NumaSection { single_node, rows }
}

/// Trace-replay instrument: the committed golden trace re-driven at
/// 16x against the routing/fuse/cache configurations the earlier
/// sections measured with synthetic load — so those sections are also
/// machine-comparable on a *fixed recorded workload* across PRs. The
/// per-config results checksums are asserted equal: serving
/// configuration may change placement and timing, never reply bits.
fn replay_rows() -> Vec<ReplayBenchRow> {
    println!("== trace replay: golden trace vs serving configurations (16x)");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("traces/golden.fftrace");
    let trace = match Trace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            println!("  (skipping: cannot load {}: {e})", path.display());
            return Vec::new();
        }
    };
    let configs: Vec<(&'static str, ServiceSpec)> = vec![
        ("single-rr", ServiceSpec::uniform(BackendSpec::native_single(), 1)),
        (
            "sharded-measured",
            ServiceSpec::uniform(BackendSpec::native(), 2).with_routing(Routing::Measured),
        ),
        (
            "fused-cached",
            ServiceSpec::uniform(BackendSpec::native(), 2)
                .with_fuse_window(Duration::from_millis(1))
                .with_fuse_sizes(vec![1024, 4096, 16384, 65536])
                .with_cache_mb(64),
        ),
    ];
    let mut rows = Vec::new();
    for (config, spec) in configs {
        let svc = Service::start(spec).unwrap();
        let rep = replay(&svc, &trace, 16.0).unwrap();
        let p95_ms_max = rep.per_op.iter().map(|r| r.p95_ms).fold(0.0f64, f64::max);
        println!(
            "  {config:<16} {} records at {:.0}x: wall={:.3}s pad={:>4.1}% \
             cache-hit={:>3.0}% worst-p95={:.2}ms fnv={:#018x}",
            rep.records,
            rep.rate,
            rep.wall_s,
            rep.padding_waste * 100.0,
            rep.cache_hit_rate * 100.0,
            p95_ms_max,
            rep.results_fnv,
        );
        rows.push(ReplayBenchRow {
            config,
            records: rep.records,
            rate: rep.rate,
            wall_s: rep.wall_s,
            padding_waste: rep.padding_waste,
            cache_hit_rate: rep.cache_hit_rate,
            results_fnv: rep.results_fnv,
            p95_ms_max,
        });
    }
    assert!(
        rows.windows(2).all(|w| w[0].results_fnv == w[1].results_fnv),
        "replay results checksum must be config-independent"
    );
    rows
}

/// A 1 ms-deadline ticket against a saturated shard must resolve
/// `DeadlineExceeded` promptly — and the shard must survive to serve
/// the next request (the ROADMAP's "a stuck canary can't hold a
/// client").
fn deadline_demo() {
    println!("== deadline: 1 ms ticket against a saturated gpusim shard");
    let svc =
        Service::start(ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1)).unwrap();
    let wl = WorkloadGen::from_env("deadline_demo");
    let h = svc.handle();
    // saturate: one big soft-float batch keeps the shard busy for a
    // while (the interpretive VM needs well over the sleep+deadline
    // even on a fast host)
    let sat = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 400_000, 1)).unwrap())
        .unwrap();
    // let the shard drain the saturating request into execution (if it
    // somehow hasn't, the probe is batched with it and merely executes
    // — the client-side deadline verdict below holds either way)
    std::thread::sleep(Duration::from_millis(50));
    let probe = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 4096, 2)).unwrap())
        .unwrap()
        .deadline(Duration::from_millis(1));
    let t0 = Instant::now();
    let err = probe.wait().expect_err("saturated shard cannot answer in 1ms");
    let waited = t0.elapsed();
    assert_eq!(err, ServiceError::DeadlineExceeded, "got {err}");
    assert!(
        waited < Duration::from_secs(1),
        "deadline miss took {waited:?} to surface — the wait blocked"
    );
    // the saturating request still completes...
    sat.wait().unwrap();
    // ...and the shard is alive for new work
    h.dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 1024, 3)).unwrap())
        .unwrap()
        .wait()
        .unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let m = svc.metrics();
    // shard-side skip accounting is best-effort here: if the shard was
    // descheduled past the sleep it batched the probe with the
    // saturating request before the deadline passed (no skip recorded)
    if m.cancelled + m.expired == 0 {
        println!("  (note: probe executed in the saturating batch; no shard-side skip)");
    }
    println!(
        "  miss surfaced in {:.2}ms; shard survived (skipped={} cancelled={})",
        waited.as_secs_f64() * 1e3,
        m.expired,
        m.cancelled
    );
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();

    // pooled-vs-persistent: raw execute throughput at small batches
    rows.extend(exec_rows());

    // fused vs unfused serving: many tiny concurrent requests — the
    // shape cross-request fusion exists for. Same workload, same
    // shards; only the window/ladder differ.
    println!("== serving tiny requests: fusion off vs 1 ms window + ladder");
    for (fuse, label) in [(false, "native-unfused"), (true, "native-fused")] {
        let mut spec =
            ServiceSpec::uniform(BackendSpec::native(), 2).with_max_batch(128);
        if fuse {
            spec = spec
                .with_fuse_window(Duration::from_millis(1))
                .with_fuse_sizes(vec![1024, 4096, 16384, 65536]);
        }
        rows.extend(run_case(label, spec, 8, 1024, 100, false));
    }

    // the seed path: single shard, single worker — the baseline every
    // sharded/parallel configuration must beat
    println!("== native (single shard, single worker — seed behaviour)");
    for (clients, req_n, rounds) in
        [(1usize, 4096usize, 200usize), (4, 4096, 100), (8, 1000, 100), (4, 100_000, 20)]
    {
        rows.extend(run_case(
            "native-seed",
            ServiceSpec::uniform(BackendSpec::native_single(), 1),
            clients, req_n, rounds, false,
        ));
    }

    // sharded native: N device threads, each a multicore worker pool
    println!("== native, sharded");
    for shards in [2usize, 4] {
        for (clients, req_n, rounds) in [(4usize, 4096usize, 100usize), (8, 1000, 100), (4, 100_000, 20)] {
            rows.extend(run_case(
                "native",
                ServiceSpec::uniform(BackendSpec::native(), shards),
                clients, req_n, rounds, false,
            ));
        }
    }

    // routing-policy comparison over a heterogeneous shard set:
    // 3 native workhorses + 1 gpusim-ieee canary (the soft-float VM is
    // orders of magnitude slower, so placement policy dominates —
    // round-robin stalls on the canary, queue-depth starves it
    // reactively, op-affinity pins one op of the mix to it, measured
    // starves it from telemetry after a cold probe per op)
    println!("== routing policies (heterogeneous: native*3 + gpusim-ieee canary)");
    let mut canary_by_policy: Vec<(&'static str, f64)> = Vec::new();
    for routing in Routing::ALL {
        let spec = ServiceSpec::heterogeneous(vec![
            BackendSpec::native(),
            BackendSpec::native(),
            BackendSpec::native(),
            BackendSpec::gpusim_ieee(),
        ])
        .with_routing(routing);
        if let Some(row) = run_case("hetero-canary", spec, 4, 2048, 20, true) {
            if let Some(share) = row.canary_share {
                canary_by_policy.push((routing.name(), share));
            }
            rows.push(row);
        }
    }
    // routing quality: measured must send strictly less slow-op traffic
    // to the canary than blind round-robin
    let share = |name: &str| {
        canary_by_policy.iter().find(|(n, _)| *n == name).map(|&(_, s)| s)
    };
    if let (Some(rr), Some(me)) = (share("round-robin"), share("measured")) {
        println!(
            "  canary share of mul22/div22: round-robin={:.0}% measured={:.0}%",
            rr * 100.0, me * 100.0
        );
        assert!(
            me < rr,
            "measured routing must starve the slow canary: measured={me:.3} vs \
             round-robin={rr:.3}"
        );
    }

    // the same policy sweep with the fusion ladder armed: every policy
    // row now carries a real padding-waste fraction (and the per-op
    // waste EWMA feeds the shard telemetry), so fusion quality is
    // machine-comparable across policies and PRs
    println!("== routing policies, fused (1 ms window + ladder): padding waste per policy");
    for routing in Routing::ALL {
        let spec = ServiceSpec::heterogeneous(vec![
            BackendSpec::native(),
            BackendSpec::native(),
            BackendSpec::native(),
            BackendSpec::gpusim_ieee(),
        ])
        .with_routing(routing)
        .with_fuse_window(Duration::from_millis(1))
        .with_fuse_sizes(vec![1024, 4096, 16384, 65536]);
        rows.extend(run_case("hetero-fused", spec, 4, 2048, 20, true));
    }

    deadline_demo();

    // the gpusim stream VM: a software model of 2006 GPU arithmetic —
    // tiny workload, the point is trajectory not absolute speed
    println!("== gpusim (IEEE model stream VM)");
    rows.extend(run_case(
        "gpusim-ieee",
        ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1),
        2, 4096, 5, false,
    ));

    // xla artifacts when present
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if artifacts.join("manifest.json").exists() {
        println!("== xla (PJRT artifacts)");
        for (clients, req_n, rounds) in [(4usize, 4096usize, 100usize), (4, 100_000, 20)] {
            rows.extend(run_case(
                "xla",
                ServiceSpec::uniform(
                    BackendSpec::Xla { artifacts: artifacts.clone(), precompile: true },
                    1,
                ),
                clients, req_n, rounds, false,
            ));
        }
    } else {
        println!("(skipping xla backend: no artifacts)");
    }

    // per-tier kernel throughput: the SIMD/FMA engine's perf surface
    let tiers = kernel_tier_rows();

    // the live accuracy surface: Table 2/5 as a continuous experiment
    let accuracy = observatory_rows();

    // the TCP serving surface: loopback overhead and pushback
    let wire = wire_rows();

    // the result cache and waste-fed fuse-ladder planning
    let mut cache = cache_rows();
    cache.extend(ladder_rows());

    // the NUMA-aware data path: staged-vs-serial copy split, then
    // pinned-vs-unpinned sharded serving
    let data_path = data_path_rows();
    let numa = numa_rows();

    // the golden trace across serving configurations
    let replays = replay_rows();

    emit_json(&rows, &tiers, &accuracy, &wire, &cache, &data_path, &numa, &replays);
}
