//! Bench: coordinator throughput/latency across backends, shard
//! counts and routing policies — the L3 hot path.
//!
//! Not a paper table (the paper has no serving layer); this is the
//! §Perf instrument for the backend layer: requests/s and Melem/s for
//! native single-shard (the seed's serving behaviour), native sharded,
//! the gpusim stream VM, XLA when artifacts exist, and — since the
//! Op/Plan redesign — a routing-policy comparison (round-robin vs
//! queue-depth vs op-affinity) over a heterogeneous native+gpusim
//! shard set. Results also land in `BENCH_coordinator.json` so the
//! perf trajectory is machine-readable across PRs.

use ffgpu::backend::{BackendSpec, Op};
use ffgpu::coordinator::{Plan, Routing, Service, ServiceSpec};
use ffgpu::harness::workload;
use ffgpu::util::Rng;
use std::path::PathBuf;
use std::time::Instant;

struct Row {
    backend: String,
    shards: usize,
    routing: String,
    clients: usize,
    req_n: usize,
    rounds: usize,
    req_per_s: f64,
    melem_per_s: f64,
    batches: u64,
    padding_fraction: f64,
    mean_latency_ms: f64,
}

/// Ops the routing comparison cycles through (parity subset: answers
/// are bit-identical whichever substrate serves them).
const MIX_OPS: [Op; 4] = [Op::Add22, Op::Mul22, Op::Mul12, Op::Add12];

fn run_case(
    label: &str, spec: ServiceSpec, clients: usize, req_n: usize, rounds: usize,
    mixed_ops: bool,
) -> Option<Row> {
    let shards = spec.shards.len();
    let routing = spec.routing;
    let svc = match Service::start(spec) {
        Ok(s) => s,
        Err(e) => {
            println!("  (skipping {label} x{shards}: {e})");
            return None;
        }
    };
    // warmup every shard (touch each one explicitly via its own op mix),
    // then let the shard threads finish recording their latency samples
    // before snapshotting: metrics for a batch land *after* its reply,
    // so an immediate snapshot would race and charge warmup cost to the
    // measured phase
    let h = svc.handle();
    for i in 0..shards.max(1) * 2 {
        let op = if mixed_ops { MIX_OPS[i % MIX_OPS.len()] } else { Op::Add22 };
        let planes = workload::planes_for(op.name(), req_n, 1 + i as u64);
        h.dispatch(Plan::new(op, planes).unwrap()).unwrap().wait().unwrap();
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    let warm = svc.metrics();

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            for round in 0..rounds {
                let op = if mixed_ops {
                    MIX_OPS[(c + round) % MIX_OPS.len()]
                } else {
                    Op::Add22
                };
                let planes = workload::planes_for(op.name(), req_n, rng.next_u64());
                h.dispatch(Plan::new(op, planes).unwrap())
                    .unwrap()
                    .wait()
                    .unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    // same settle as the warmup snapshot: the final batch's latency
    // sample lands after its reply, so don't snapshot under the race
    std::thread::sleep(std::time::Duration::from_millis(50));
    let m = svc.metrics();
    let total_req = (clients * rounds) as f64;
    let total_elems = total_req * req_n as f64;
    // measured-phase deltas (warmup excluded)
    let batches = m.batches - warm.batches;
    let elements = m.elements - warm.elements;
    let padded = m.padded_elements - warm.padded_elements;
    let lat_count = m.latency_count - warm.latency_count;
    let mean_latency_s = if lat_count > 0 {
        (m.mean_latency_s * m.latency_count as f64
            - warm.mean_latency_s * warm.latency_count as f64)
            / lat_count as f64
    } else {
        0.0
    };
    let padding_fraction = if elements + padded > 0 {
        padded as f64 / (elements + padded) as f64
    } else {
        0.0
    };
    let row = Row {
        backend: label.to_string(),
        shards,
        routing: routing.name().to_string(),
        clients,
        req_n,
        rounds,
        req_per_s: total_req / wall,
        melem_per_s: total_elems / wall / 1e6,
        batches,
        padding_fraction,
        mean_latency_ms: mean_latency_s * 1e3,
    };
    println!(
        "  {label:<16} shards={shards} routing={:<11} {clients} clients x {req_n:>6} elems: \
         {:>8.0} req/s  {:>7.1} Melem/s  batches={:<5} pad={:>4.1}%  lat mean={:.2}ms",
        row.routing,
        row.req_per_s,
        row.melem_per_s,
        row.batches,
        row.padding_fraction * 100.0,
        row.mean_latency_ms,
    );
    Some(row)
}

fn emit_json(rows: &[Row]) {
    let mut out = String::from("{\n  \"bench\": \"coordinator\",\n  \"unit\": {\"req_per_s\": \"requests/s\", \"melem_per_s\": \"1e6 elements/s\"},\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"backend\": \"{}\", \"shards\": {}, \"routing\": \"{}\", \
             \"clients\": {}, \"req_n\": {}, \"rounds\": {}, \"req_per_s\": {:.1}, \
             \"melem_per_s\": {:.3}, \"batches\": {}, \
             \"padding_fraction\": {:.4}, \"mean_latency_ms\": {:.3}}}{}\n",
            r.backend,
            r.shards,
            r.routing,
            r.clients,
            r.req_n,
            r.rounds,
            r.req_per_s,
            r.melem_per_s,
            r.batches,
            r.padding_fraction,
            r.mean_latency_ms,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    let path = "BENCH_coordinator.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("\nwrote {path} ({} rows)", rows.len()),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();

    // the seed path: single shard, single worker — the baseline every
    // sharded/parallel configuration must beat
    println!("== native (single shard, single worker — seed behaviour)");
    for (clients, req_n, rounds) in
        [(1usize, 4096usize, 200usize), (4, 4096, 100), (8, 1000, 100), (4, 100_000, 20)]
    {
        rows.extend(run_case(
            "native-seed",
            ServiceSpec::uniform(BackendSpec::native_single(), 1),
            clients, req_n, rounds, false,
        ));
    }

    // sharded native: N device threads, each a multicore worker pool
    println!("== native, sharded");
    for shards in [2usize, 4] {
        for (clients, req_n, rounds) in [(4usize, 4096usize, 100usize), (8, 1000, 100), (4, 100_000, 20)] {
            rows.extend(run_case(
                "native",
                ServiceSpec::uniform(BackendSpec::native(), shards),
                clients, req_n, rounds, false,
            ));
        }
    }

    // routing-policy comparison over a heterogeneous shard set:
    // 3 native workhorses + 1 gpusim-ieee canary (the soft-float VM is
    // orders of magnitude slower, so placement policy dominates —
    // queue-depth should starve the canary, round-robin stalls on it,
    // op-affinity pins one op of the mix to it)
    println!("== routing policies (heterogeneous: native*3 + gpusim-ieee canary)");
    for routing in Routing::ALL {
        let spec = ServiceSpec::heterogeneous(vec![
            BackendSpec::native(),
            BackendSpec::native(),
            BackendSpec::native(),
            BackendSpec::gpusim_ieee(),
        ])
        .with_routing(routing);
        rows.extend(run_case("hetero-canary", spec, 4, 2048, 10, true));
    }

    // the gpusim stream VM: a software model of 2006 GPU arithmetic —
    // tiny workload, the point is trajectory not absolute speed
    println!("== gpusim (IEEE model stream VM)");
    rows.extend(run_case(
        "gpusim-ieee",
        ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1),
        2, 4096, 5, false,
    ));

    // xla artifacts when present
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if artifacts.join("manifest.json").exists() {
        println!("== xla (PJRT artifacts)");
        for (clients, req_n, rounds) in [(4usize, 4096usize, 100usize), (4, 100_000, 20)] {
            rows.extend(run_case(
                "xla",
                ServiceSpec::uniform(
                    BackendSpec::Xla { artifacts: artifacts.clone(), precompile: true },
                    1,
                ),
                clients, req_n, rounds, false,
            ));
        }
    } else {
        println!("(skipping xla backend: no artifacts)");
    }

    emit_json(&rows);
}
