//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. branch-free vs branchy Add22 (paper §4: "we should avoid tests
//!    even at the expense of extra computations") — on a 2026 OoO core
//!    vs what the paper saw on a Pentium IV;
//! 2. mask split vs FP-only Dekker split (our §4b workaround vs the
//!    paper-verbatim sequence) — cost of the workaround;
//! 3. sloppy (11-flop) vs accurate (20-flop) Add22 — accuracy/cost
//!    trade the double-double literature debates;
//! 4. two_prod (17-flop Dekker) vs two_prod_fma (2-flop hardware FMA) —
//!    what 2006 GPUs were missing.

use ffgpu::ff::{self, FF32};
use ffgpu::util::{Rng, Timer};

fn planes(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut out = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for _ in 0..n {
        let (h, l) = rng.ff_pair(-8, 8);
        out.0.push(h);
        out.1.push(l);
        let (h, l) = rng.ff_pair(-8, 8);
        out.2.push(h);
        out.3.push(l);
    }
    out
}

fn main() {
    let n = 1 << 20;
    let timer = Timer::new(3, 9);
    let (ah, al, bh, bl) = planes(n, 0xAB1A);
    let mut rh = vec![0.0f32; n];
    let mut rl = vec![0.0f32; n];

    println!("ablations over {n} elements (median of 9)\n");

    // 1. branch-free vs branchy Add22
    let t_free = timer.median_secs(|| {
        ff::vector::add22(&ah, &al, &bh, &bl, &mut rh, &mut rl);
        std::hint::black_box(&rh);
    });
    let t_branchy = timer.median_secs(|| {
        ff::vector::add22_branchy(&ah, &al, &bh, &bl, &mut rh, &mut rl);
        std::hint::black_box(&rh);
    });
    println!("add22 branch-free : {:.3} ms", t_free * 1e3);
    println!("add22 branchy     : {:.3} ms  ({:+.0}% vs branch-free; paper saw ~2.8x on P4)",
             t_branchy * 1e3, (t_branchy / t_free - 1.0) * 100.0);

    // 2. mask vs Dekker split
    let a: Vec<f32> = ah.clone();
    let t_mask = timer.median_secs(|| {
        let mut acc = 0.0f32;
        for &v in &a {
            let (h, l) = ff::split(v);
            acc += h + l;
        }
        std::hint::black_box(acc);
    });
    let t_dekker = timer.median_secs(|| {
        let mut acc = 0.0f32;
        for &v in &a {
            let (h, l) = ff::split_dekker(v);
            acc += h + l;
        }
        std::hint::black_box(acc);
    });
    println!("\nsplit mask        : {:.3} ms", t_mask * 1e3);
    println!("split dekker (FP) : {:.3} ms  ({:+.0}%)",
             t_dekker * 1e3, (t_dekker / t_mask - 1.0) * 100.0);

    // 3. sloppy vs accurate Add22: cost + accuracy on cancelling data
    let t_acc = timer.median_secs(|| {
        for i in 0..n {
            let r = FF32::from_parts(ah[i], al[i])
                .add22_accurate(FF32::from_parts(bh[i], bl[i]));
            rh[i] = r.hi;
            rl[i] = r.lo;
        }
        std::hint::black_box(&rh);
    });
    println!("\nadd22 sloppy(11op): {:.3} ms", t_free * 1e3);
    println!("add22 accurate(20): {:.3} ms  ({:+.0}%)",
             t_acc * 1e3, (t_acc / t_free - 1.0) * 100.0);
    // accuracy on adversarial (cancelling) inputs
    let mut rng = Rng::new(7);
    let (mut worst_sloppy, mut worst_acc) = (0.0f64, 0.0f64);
    for _ in 0..200_000 {
        let (h, l) = rng.ff_pair(-4, 4);
        let a = FF32::from_parts(h, l);
        let b = FF32::from_parts(-h, (l as f64 * 0.9) as f32); // near-cancel
        let want = a.to_f64() + b.to_f64();
        if want == 0.0 {
            continue;
        }
        worst_sloppy = worst_sloppy.max(((a.add22(b).to_f64() - want) / want).abs());
        worst_acc = worst_acc.max(((a.add22_accurate(b).to_f64() - want) / want).abs());
    }
    println!("  worst rel err under cancellation: sloppy 2^{:.1}, accurate 2^{:.1}",
             worst_sloppy.log2(), worst_acc.log2());

    // 4. Dekker two_prod vs hardware FMA
    let t_dek = timer.median_secs(|| {
        let mut acc = 0.0f32;
        for i in 0..n {
            let (x, y) = ff::two_prod(ah[i], bh[i]);
            acc += x + y;
        }
        std::hint::black_box(acc);
    });
    let t_fma = timer.median_secs(|| {
        let mut acc = 0.0f32;
        for i in 0..n {
            let (x, y) = ff::two_prod_fma(ah[i], bh[i]);
            acc += x + y;
        }
        std::hint::black_box(acc);
    });
    println!("\ntwo_prod dekker   : {:.3} ms  (the 2006-GPU 17-flop path)", t_dek * 1e3);
    println!("two_prod fma      : {:.3} ms  ({:.1}x — what shader model 3.0 lacked)",
             t_fma * 1e3, t_dek / t_fma);
}
