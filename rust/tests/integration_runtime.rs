//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` (tests no-op with a notice when the
//! directory is missing — CI always builds artifacts first).

use ffgpu::coordinator::batcher::op_arity;
use ffgpu::ff::{compensated, FF32};
use ffgpu::harness::workload;
use ffgpu::mp::Dyadic;
use ffgpu::runtime::Runtime;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn all_stream_ops_bit_match_native_at_4096() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for op in workload::PAPER_OPS.iter().chain(workload::EXT_OPS.iter()) {
        let planes = workload::planes_for(op, 4096, 0xBEEF);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let xla = rt.execute(&format!("{op}_n4096"), &refs).unwrap();
        let (_, n_out) = op_arity(op).unwrap();
        let mut native = vec![vec![0.0f32; 4096]; n_out];
        ffgpu::ff::vector::dispatch(op, &refs, &mut native).unwrap();
        for (o, (a, b)) in xla.iter().zip(&native).enumerate() {
            for i in 0..4096 {
                assert_eq!(
                    a[i].to_bits(), b[i].to_bits(),
                    "{op} out{o} lane {i}: xla={} native={}", a[i], b[i]
                );
            }
        }
    }
}

#[test]
fn large_sizes_bit_match_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    for (op, n) in [("mul12", 65536usize), ("add22", 262144), ("mul22", 1048576)] {
        let planes = workload::planes_for(op, n, 0xCAFE);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let xla = rt.execute(&format!("{op}_n{n}"), &refs).unwrap();
        let (_, n_out) = op_arity(op).unwrap();
        let mut native = vec![vec![0.0f32; n]; n_out];
        ffgpu::ff::vector::dispatch(op, &refs, &mut native).unwrap();
        for (a, b) in xla.iter().zip(&native) {
            let bad = a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
            assert_eq!(bad, 0, "{op}@{n}: {bad} lanes differ");
        }
    }
}

#[test]
fn mul12_exactness_through_artifacts() {
    // Th. 4 holds through the whole AOT+PJRT stack (DESIGN.md §4b is the
    // regression this guards).
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let n = 65536;
    let planes = workload::planes_for("mul12", n, 0xD00D);
    let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
    let out = rt.execute(&format!("mul12_n{n}"), &refs).unwrap();
    for i in 0..n {
        let exact = Dyadic::from_f32(planes[0][i]).mul(&Dyadic::from_f32(planes[1][i]));
        let got = Dyadic::from_ff(out[0][i], out[1][i]);
        assert!(got.sub(&exact).is_zero(), "lane {i} not exact");
    }
}

#[test]
fn add12_exactness_through_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let n = 16384;
    let planes = workload::planes_for("add12", n, 0xD11D);
    let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
    let out = rt.execute(&format!("add12_n{n}"), &refs).unwrap();
    for i in 0..n {
        let exact = Dyadic::from_f32(planes[0][i]).add(&Dyadic::from_f32(planes[1][i]));
        let got = Dyadic::from_ff(out[0][i], out[1][i]);
        assert!(got.sub(&exact).is_zero(), "lane {i} not exact");
    }
}

#[test]
fn dot2_artifact_matches_native_pairwise() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let name = "dot2_n65536";
    if rt.manifest().get(name).is_none() {
        eprintln!("skipping: {name} not in manifest");
        return;
    }
    let n = 65536;
    let planes = workload::planes_for("mul22", n, 0xA11A); // 4 ff planes
    let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
    let out = rt.execute(name, &refs).unwrap();
    assert_eq!(out[0].len(), 1);
    let got = FF32::from_parts(out[0][0], out[1][0]);
    let native =
        compensated::dot_ff_pairwise(&planes[0], &planes[1], &planes[2], &planes[3]);
    assert_eq!(got.hi.to_bits(), native.hi.to_bits(), "dot2 hi differs");
    assert_eq!(got.lo.to_bits(), native.lo.to_bits(), "dot2 lo differs");
}

#[test]
fn horner2_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let name = "horner2_d31";
    let Some(entry) = rt.manifest().get(name).cloned() else {
        eprintln!("skipping: {name} not in manifest");
        return;
    };
    let deg1 = entry.n; // degree + 1 coefficients
    let planes = workload::planes_for("mul22", deg1, 0xB22B);
    let (ch, cl) = (&planes[0], &planes[1]);
    let x = FF32::from_f64(0.73);
    let (xh, xl) = ([x.hi], [x.lo]);
    let inputs: Vec<&[f32]> = vec![ch, cl, &xh, &xl];
    let out = rt.execute(name, &inputs).unwrap();
    let got = FF32::from_parts(out[0][0], out[1][0]);
    let native = compensated::horner_ff(ch, cl, x);
    assert_eq!(got.hi.to_bits(), native.hi.to_bits());
    assert_eq!(got.lo.to_bits(), native.lo.to_bits());
}

#[test]
fn multipass_artifact_matches_native_iteration() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let entry = rt
        .manifest()
        .entries
        .iter()
        .find(|e| e.kind == "multipass")
        .cloned();
    let Some(entry) = entry else {
        eprintln!("skipping: no multipass artifact");
        return;
    };
    let n = entry.n;
    // iters encoded in the name: multipass_n{n}_k{iters}
    let iters: usize = entry
        .name
        .rsplit('_')
        .next()
        .and_then(|s| s.strip_prefix('k'))
        .and_then(|s| s.parse().ok())
        .expect("iters in name");
    let mut planes = workload::planes_for("mul22", n, 0xC33C);
    // keep |b| < 1 so the iteration stays bounded
    for i in 0..n {
        let b = FF32::from_f64(
            (planes[2][i] as f64).rem_euclid(1.8) - 0.9,
        );
        planes[2][i] = b.hi;
        planes[3][i] = b.lo;
    }
    let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
    let out = rt.execute(&entry.name, &refs).unwrap();
    for i in (0..n).step_by(97) {
        let a = FF32::from_parts(planes[0][i], planes[1][i]);
        let b = FF32::from_parts(planes[2][i], planes[3][i]);
        let mut x = a;
        for _ in 0..iters {
            x = x * b + a;
        }
        assert_eq!(
            (out[0][i].to_bits(), out[1][i].to_bits()),
            (x.hi.to_bits(), x.lo.to_bits()),
            "lane {i}"
        );
    }
}

#[test]
fn runtime_rejects_bad_shapes_and_names() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.execute("nope_n1", &[]).is_err());
    let too_short = vec![0.0f32; 16];
    assert!(rt.execute("add_n4096", &[&too_short, &too_short]).is_err());
    let ok = vec![0.0f32; 4096];
    assert!(rt.execute("add_n4096", &[&ok]).is_err()); // wrong arity
}

#[test]
fn coordinator_lifecycle_over_the_xla_service() {
    // deadline/cancel tickets work end-to-end through the artifact
    // path, not just the always-available substrates
    let Some(dir) = artifacts_dir() else { return };
    use ffgpu::backend::{BackendSpec, Op, ServiceError};
    use ffgpu::coordinator::{Plan, Service, ServiceSpec};
    let svc = Service::start(ServiceSpec::uniform(
        BackendSpec::Xla { artifacts: dir, precompile: false },
        1,
    ))
    .unwrap();
    let h = svc.handle();
    // a generous deadline resolves normally through PJRT
    let planes = workload::planes_for("add22", 4096, 0xDEAD);
    let out = h
        .dispatch(Plan::new(Op::Add22, planes).unwrap())
        .unwrap()
        .deadline(std::time::Duration::from_secs(30))
        .wait()
        .unwrap();
    assert_eq!(out[0].len(), 4096);
    // a pre-cancelled ticket resolves Cancelled and the service stays up
    let t = h
        .dispatch(Plan::new(Op::Add22, workload::planes_for("add22", 512, 1)).unwrap())
        .unwrap();
    t.cancel();
    assert_eq!(t.wait(), Err(ServiceError::Cancelled));
    let out = h
        .dispatch(Plan::new(Op::Add22, workload::planes_for("add22", 512, 2)).unwrap())
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out[0].len(), 512);
}

#[test]
fn runtime_stats_accumulate() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let a = vec![1.0f32; 4096];
    let b = vec![2.0f32; 4096];
    for _ in 0..3 {
        rt.execute("add_n4096", &[&a, &b]).unwrap();
    }
    let st = rt.stats();
    assert_eq!(st.compiled, 1);
    assert_eq!(st.executions, 3);
    assert!(st.execute_seconds > 0.0);
}
