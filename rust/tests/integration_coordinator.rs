//! Integration: the coordinator service end-to-end over the XLA backend.

use ffgpu::backend::BackendSpec;
use ffgpu::coordinator::{Service, ServiceConfig};
use ffgpu::ff::FF32;
use ffgpu::harness::workload;
use ffgpu::util::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn xla_spec(dir: PathBuf) -> BackendSpec {
    BackendSpec::Xla { artifacts: dir, precompile: false }
}

fn xla_service(dir: PathBuf) -> Service {
    Service::start(ServiceConfig {
        backend: xla_spec(dir),
        shards: 1,
        max_batch: 32,
    })
    .expect("service start")
}

/// Native reference for one request.
fn expect_add22(planes: &[Vec<f32>]) -> Vec<(f32, f32)> {
    (0..planes[0].len())
        .map(|i| {
            let r = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            (r.hi, r.lo)
        })
        .collect()
}

#[test]
fn odd_sizes_are_padded_and_correct() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = xla_service(dir);
    let h = svc.handle();
    // sizes that don't match any artifact: padding and windowing paths
    for n in [1usize, 7, 100, 4095, 4097, 10_000] {
        let planes = workload::planes_for("add22", n, n as u64);
        let out = h.call("add22", planes.clone()).unwrap();
        assert_eq!(out[0].len(), n);
        let want = expect_add22(&planes);
        for i in 0..n {
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (want[i].0.to_bits(), want[i].1.to_bits()),
                "n={n} lane={i}"
            );
        }
    }
    let m = svc.metrics();
    assert!(m.padded_elements > 0, "padding path untested");
}

#[test]
fn oversize_requests_split_across_launches() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = xla_service(dir);
    let h = svc.handle();
    // bigger than the largest artifact (1048576): forces multi-launch
    let n = 1_200_000;
    let planes = workload::planes_for("add", n, 99);
    let out = h.call("add", planes.clone()).unwrap();
    for i in (0..n).step_by(10_007) {
        assert_eq!(out[0][i], planes[0][i] + planes[1][i], "lane {i}");
    }
    let m = svc.metrics();
    assert!(m.launches >= 2, "expected a split, got {} launches", m.launches);
}

#[test]
fn mixed_ops_from_concurrent_clients() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = xla_service(dir);
    let mut joins = Vec::new();
    for t in 0..6u64 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let ops = ["add", "mul12", "add22", "mul22"];
            for round in 0..10 {
                let op = ops[(t as usize + round) % ops.len()];
                let n = 500 + rng.below(5000);
                let planes = workload::planes_for(op, n, rng.next_u64());
                let out = h.call(op, planes.clone()).unwrap();
                // spot check against native
                let (_, n_out) =
                    ffgpu::coordinator::batcher::op_arity(op).unwrap();
                let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
                let mut native = vec![vec![0.0f32; n]; n_out];
                ffgpu::ff::vector::dispatch(op, &refs, &mut native).unwrap();
                for i in (0..n).step_by(131) {
                    assert_eq!(out[0][i].to_bits(), native[0][i].to_bits(),
                               "op={op} n={n} lane={i}");
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.requests, 60);
    assert_eq!(m.errors, 0);
}

#[test]
fn batching_coalesces_same_op_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = Service::start(ServiceConfig {
        backend: xla_spec(dir),
        shards: 1,
        max_batch: 64,
    })
    .unwrap();
    // submit many small async requests before the device thread drains
    let h = svc.handle();
    let mut pending = Vec::new();
    let mut wants = Vec::new();
    for k in 0..40 {
        let planes = workload::planes_for("add22", 50 + k, k as u64);
        wants.push(expect_add22(&planes));
        pending.push(h.submit("add22", planes).unwrap());
    }
    for (rx, want) in pending.into_iter().zip(wants) {
        let out = rx.recv().unwrap().unwrap();
        for (i, (h_, l_)) in want.iter().enumerate() {
            assert_eq!((out[0][i], out[1][i]), (*h_, *l_), "lane {i}");
        }
    }
    let m = svc.metrics();
    assert!(
        m.batches < m.requests,
        "no coalescing happened: {} batches for {} requests",
        m.batches, m.requests
    );
}

#[test]
fn cpu_and_xla_backends_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = xla_service(dir);
    let cpu = Service::start(ServiceConfig::default()).unwrap();
    for op in ["add12", "mul12", "add22", "mul22", "div22"] {
        let planes = workload::planes_for(op, 3000, 0xE44E);
        let a = xla.handle().call(op, planes.clone()).unwrap();
        let b = cpu.handle().call(op, planes).unwrap();
        for (pa, pb) in a.iter().zip(&b) {
            for i in 0..pa.len() {
                assert_eq!(pa[i].to_bits(), pb[i].to_bits(), "op={op} lane={i}");
            }
        }
    }
}
