//! Integration: the coordinator service end-to-end — heterogeneous
//! native+gpusim shard sets with routing policies, telemetry-driven
//! measured placement, ticket deadlines/cancellation, the fusion
//! stage's cross-request batch packing, and the result cache's
//! isolation from routing telemetry and the observatory (always
//! runnable), plus the XLA backend paths when artifacts exist.

mod common;

use common::WorkloadGen;
use ffgpu::backend::{BackendSpec, Op, ServiceError};
use ffgpu::coordinator::observatory::one_shot_sweep;
use ffgpu::coordinator::routing::OpAffinity;
use ffgpu::coordinator::{ObservatorySpec, Plan, Routing, Service, ServiceSpec};
use ffgpu::ff::FF32;
use ffgpu::harness::workload;
use ffgpu::util::Rng;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn xla_spec(dir: PathBuf) -> BackendSpec {
    BackendSpec::Xla { artifacts: dir, precompile: false }
}

fn xla_service(dir: PathBuf) -> Service {
    Service::start(ServiceSpec::uniform(xla_spec(dir), 1).with_max_batch(32))
        .expect("service start")
}

fn call(svc: &Service, op: Op, planes: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
    svc.handle()
        .dispatch(Plan::new(op, planes).expect("plan"))
        .expect("dispatch")
        .wait()
        .expect("reply")
}

/// Native reference for one request.
fn expect_add22(planes: &[Vec<f32>]) -> Vec<(f32, f32)> {
    (0..planes[0].len())
        .map(|i| {
            let r = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            (r.hi, r.lo)
        })
        .collect()
}

/// Satellite: a mixed native+gpusim shard set must agree bit-for-bit
/// on the EFT parity ops, and per-shard metrics must attribute every
/// request to the shard the routing policy picked.
#[test]
fn heterogeneous_shard_set_bit_parity_and_attribution() {
    let svc = Service::start(
        ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::native_single(),
            BackendSpec::gpusim_ieee(),
        ])
        .with_routing(Routing::OpAffinity),
    )
    .unwrap();
    assert_eq!(svc.shard_labels(), vec!["native", "native", "gpusim"]);
    assert_eq!(svc.routing(), "op-affinity");

    let wl = WorkloadGen::from_env("heterogeneous_shard_set");
    let parity_ops = [Op::Add12, Op::Mul12, Op::Add22, Op::Mul22, Op::Mad22];
    let per_op = 4usize;
    let h = svc.handle();
    let mut reference = ffgpu::backend::NativeBackend::new(1 << 20, 1);
    for op in parity_ops {
        for round in 0..per_op {
            let n = 100 + 37 * round;
            let planes = wl.planes(op, n, (op.index() * 10 + round) as u64);
            // typed dispatch, and the ticket reports the policy's pick
            let ticket = h.dispatch(Plan::new(op, planes.clone()).unwrap()).unwrap();
            assert_eq!(ticket.shard(), OpAffinity::home(op, 3), "{op}");
            let got = ticket.wait().unwrap();
            // bit-parity with the single-threaded native reference,
            // whichever substrate served it
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let mut want = vec![vec![0.0f32; n]; op.n_out()];
            use ffgpu::backend::KernelBackend;
            reference.execute_planes(op, &refs, &mut want).unwrap();
            for (pg, pw) in got.iter().zip(&want) {
                for i in 0..n {
                    assert_eq!(
                        pg[i].to_bits(),
                        pw[i].to_bits(),
                        "op={op} round={round} lane={i}"
                    );
                }
            }
        }
    }

    // attribution: each op's requests landed exactly on its home shard
    let per_shard = svc.shard_metrics();
    let mut expected = vec![0u64; 3];
    for op in parity_ops {
        expected[OpAffinity::home(op, 3)] += per_op as u64;
    }
    let got: Vec<u64> = per_shard.iter().map(|s| s.requests).collect();
    assert_eq!(got, expected, "per-shard request attribution");
    // the gpusim canary (shard 2) really served work
    assert!(per_shard[2].requests > 0, "canary shard idle");
    assert!(per_shard[2].elements > 0);
    assert_eq!(svc.metrics().errors, 0);
}

#[test]
fn queue_depth_routing_serves_heterogeneous_set() {
    // least-loaded routing over a native + gpusim pair: everything
    // must still answer correctly regardless of placement
    let svc = Service::start(
        ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::gpusim_ieee(),
        ])
        .with_routing(Routing::QueueDepth),
    )
    .unwrap();
    let wl = WorkloadGen::from_env("queue_depth_routing");
    let h = svc.handle();
    let mut tickets = Vec::new();
    let mut wants = Vec::new();
    for k in 0..12u64 {
        let planes = wl.planes(Op::Add22, 300, k);
        wants.push(expect_add22(&planes));
        tickets.push(h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap());
    }
    for (t, want) in tickets.into_iter().zip(wants) {
        assert!(t.shard() < 2);
        let out = t.wait().unwrap();
        for (i, (hi, lo)) in want.iter().enumerate() {
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (hi.to_bits(), lo.to_bits()),
                "lane {i}"
            );
        }
    }
    assert_eq!(h.queue_depths(), vec![0, 0]);
    let total: u64 = svc.shard_metrics().iter().map(|s| s.requests).sum();
    assert_eq!(total, 12);
}

#[test]
fn typed_plan_dispatch_on_default_spec() {
    // the scenario the old shim test covered, first-party style:
    // typed Plan dispatch on the default single-native spec, blocking
    // and polled resolution (the deprecated shims keep their own unit
    // coverage in coordinator::service)
    let svc = Service::start(ServiceSpec::default()).unwrap();
    let h = svc.handle();
    let planes = WorkloadGen::from_env("typed_plan_dispatch").planes(Op::Add22, 500, 0xCA11);
    let want = expect_add22(&planes);
    let out = h
        .dispatch(Plan::new(Op::Add22, planes).unwrap())
        .unwrap()
        .wait()
        .unwrap();
    for (i, (hi, lo)) in want.iter().enumerate() {
        assert_eq!((out[0][i], out[1][i]), (*hi, *lo), "lane {i}");
    }
    // async shape: poll a ticket instead of blocking on it
    let plan = Plan::builder(Op::Add)
        .plane(vec![1.0, 2.0])
        .plane(vec![3.0, 4.0])
        .build()
        .unwrap();
    let ticket = h.dispatch(plan).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Some(r) = ticket.try_wait() {
            assert_eq!(r.unwrap()[0], vec![4.0, 6.0]);
            break;
        }
        assert!(Instant::now() < deadline, "poll never resolved");
        std::thread::yield_now();
    }
}

#[test]
fn measured_routing_starves_the_slow_canary() {
    // native workhorse + gpusim canary: after one cold probe per op,
    // telemetry shows the canary is orders of magnitude slower and
    // measured routing stops sending it traffic
    let svc = Service::start(
        ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::gpusim_ieee(),
        ])
        .with_routing(Routing::Measured),
    )
    .unwrap();
    assert_eq!(svc.routing(), "measured");
    let wl = WorkloadGen::from_env("measured_routing");
    let h = svc.handle();
    let rounds = 16usize;
    let mut canary = 0usize;
    for k in 0..rounds {
        let planes = wl.planes(Op::Mul22, 256, k as u64);
        let ticket = h.dispatch(Plan::new(Op::Mul22, planes).unwrap()).unwrap();
        if svc.shard_labels()[ticket.shard()] == "gpusim" {
            canary += 1;
        }
        let out = ticket.wait().unwrap();
        assert_eq!(out[0].len(), 256);
    }
    // serial dispatch: exactly one cold probe can land on the canary
    // (both shards start cold; after each is measured once the native
    // shard wins every pick)
    assert!(canary <= 2, "canary got {canary}/{rounds} mul22 requests");
    assert!(canary >= 1, "exploration never probed the canary");
    // both cells are warm and the native one measures faster
    let native_rate = svc.measured_rate(0, Op::Mul22).expect("native warm");
    let canary_rate = svc.measured_rate(1, Op::Mul22).expect("canary warm");
    assert!(
        native_rate > canary_rate,
        "native {native_rate} Melem/s vs canary {canary_rate} Melem/s"
    );
    assert_eq!(svc.metrics().errors, 0);
}

#[test]
fn deadline_expired_ticket_returns_promptly_and_shard_survives() {
    // one gpusim shard saturated by a big soft-float batch: a 1 ms
    // deadline ticket must resolve DeadlineExceeded without waiting for
    // the shard, and the shard must stay live for later work
    let svc =
        Service::start(ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1)).unwrap();
    let wl = WorkloadGen::from_env("deadline_expired");
    let h = svc.handle();
    let sat = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 400_000, 1)).unwrap())
        .unwrap();
    // let the shard pull the saturating request into execution (the
    // soft-float VM needs far longer than this sleep to finish it)
    std::thread::sleep(Duration::from_millis(50));
    let probe = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 4096, 2)).unwrap())
        .unwrap()
        .deadline(Duration::from_millis(1));
    let t0 = Instant::now();
    assert_eq!(probe.wait(), Err(ServiceError::DeadlineExceeded));
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "deadline miss blocked for {:?}", t0.elapsed()
    );
    // the saturating request still completes, and the shard serves on
    sat.wait().unwrap();
    let out = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 512, 3)).unwrap())
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out[0].len(), 512);
    assert!(svc.is_running());
    // metrics land before the replies, so by now the skip is recorded
    let m = svc.metrics();
    assert!(
        m.cancelled + m.expired >= 1,
        "shard executed the abandoned probe (cancelled={} expired={})",
        m.cancelled, m.expired
    );
}

#[test]
fn cancelled_request_is_skipped_by_the_shard() {
    let svc =
        Service::start(ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1)).unwrap();
    let wl = WorkloadGen::from_env("cancelled_request");
    let h = svc.handle();
    let sat = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 400_000, 1)).unwrap())
        .unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let victim = h
        .dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 4096, 2)).unwrap())
        .unwrap();
    victim.cancel();
    assert_eq!(victim.wait(), Err(ServiceError::Cancelled));
    sat.wait().unwrap();
    // drain the queue past the victim with a fresh request
    h.dispatch(Plan::new(Op::Mul22, wl.planes(Op::Mul22, 256, 3)).unwrap())
        .unwrap()
        .wait()
        .unwrap();
    let m = svc.metrics();
    assert!(m.cancelled >= 1, "victim was executed, not skipped");
    assert_eq!(h.queue_depths(), vec![0]);
}

/// Satellite property (seeded random search): serving a burst of
/// mixed-size same-op requests through a **fusing** shard — window
/// armed, padded size ladder — is bit-identical to serving each
/// request alone. Padding lanes (including `div22`'s ones-padded
/// divisor) never leak into a reply, on native and gpusim alike.
#[test]
fn fused_batches_slice_back_bit_identically_to_solo_serving() {
    let ladder = vec![256usize, 1024, 4096, 16384];
    let wl = WorkloadGen::from_env("fused_batches");
    for backend in [BackendSpec::native_single(), BackendSpec::gpusim_ieee()] {
        let fused = Service::start(
            ServiceSpec::uniform(backend.clone(), 1)
                .with_max_batch(64)
                .with_fuse_window(Duration::from_millis(60))
                .with_fuse_sizes(ladder.clone()),
        )
        .unwrap();
        let solo = Service::start(ServiceSpec::uniform(backend, 1)).unwrap();
        let mut rng = Rng::new(0xF05E);
        for op in [Op::Add22, Op::Mul22, Op::Div22, Op::Mad22] {
            // six requests, sizes drawn to straddle the ladder's
            // smallest rungs (so plans pad, split tails, or both)
            let sizes: Vec<usize> = (0..6).map(|_| 1 + rng.below(700)).collect();
            let all: Vec<Vec<Vec<f32>>> = sizes
                .iter()
                .enumerate()
                .map(|(k, &n)| wl.planes(op, n, (op.index() * 100 + k) as u64))
                .collect();
            // burst-dispatch so the window fuses them
            let h = fused.handle();
            let tickets: Vec<_> = all
                .iter()
                .map(|p| h.dispatch(Plan::new(op, p.clone()).unwrap()).unwrap())
                .collect();
            for ((ticket, planes), n) in tickets.into_iter().zip(&all).zip(&sizes) {
                let got = ticket.wait().unwrap();
                let want = call(&solo, op, planes.clone());
                assert_eq!(got.len(), want.len(), "{op}");
                for (o, (pg, pw)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(pg.len(), *n, "{op}: reply resized by fusion");
                    for i in 0..*n {
                        assert_eq!(
                            pg[i].to_bits(),
                            pw[i].to_bits(),
                            "op={op} n={n} out{o} lane {i}"
                        );
                    }
                }
            }
        }
        let m = fused.metrics();
        assert_eq!(m.requests, 24);
        assert!(
            m.batches < m.requests,
            "fusion never happened: {} batches for {} requests",
            m.batches,
            m.requests
        );
        assert!(m.padded_elements > 0, "the ladder never padded a launch");
        assert_eq!(m.errors, 0);
    }
}

/// The persistent crew behind a serving shard survives many batches:
/// requests keep resolving correctly across rounds with no respawn
/// (the seed's scoped pool would have spawned/joined per batch).
#[test]
fn persistent_native_workers_serve_many_service_batches() {
    // chunk floor is 1024, so 5000-lane requests engage the crew
    let svc = Service::start(ServiceSpec::uniform(
        BackendSpec::Native { chunk: 1024, workers: 4, tier: None, node: None },
        1,
    ))
    .unwrap();
    let wl = WorkloadGen::from_env("persistent_native_workers");
    let h = svc.handle();
    for round in 0..6u64 {
        let n = 5000 + 617 * round as usize;
        let planes = wl.planes(Op::Add22, n, round);
        let want = expect_add22(&planes);
        let out = h
            .dispatch(Plan::new(Op::Add22, planes).unwrap())
            .unwrap()
            .wait()
            .unwrap();
        for (i, (hi, lo)) in want.iter().enumerate() {
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (hi.to_bits(), lo.to_bits()),
                "round {round} lane {i}"
            );
        }
    }
    assert_eq!(svc.metrics().requests, 6);
    assert_eq!(svc.metrics().errors, 0);
    assert!(svc.is_running());
}

#[test]
fn odd_sizes_are_padded_and_correct() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = xla_service(dir);
    let wl = WorkloadGen::from_env("odd_sizes");
    // sizes that don't match any artifact: padding and windowing paths
    for n in [1usize, 7, 100, 4095, 4097, 10_000] {
        let planes = wl.planes(Op::Add22, n, n as u64);
        let out = call(&svc, Op::Add22, planes.clone());
        assert_eq!(out[0].len(), n);
        let want = expect_add22(&planes);
        for i in 0..n {
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (want[i].0.to_bits(), want[i].1.to_bits()),
                "n={n} lane={i}"
            );
        }
    }
    let m = svc.metrics();
    assert!(m.padded_elements > 0, "padding path untested");
}

#[test]
fn oversize_requests_split_across_launches() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = xla_service(dir);
    // bigger than the largest artifact (1048576): forces multi-launch
    let n = 1_200_000;
    let planes = WorkloadGen::from_env("oversize_requests").planes(Op::Add, n, 99);
    let out = call(&svc, Op::Add, planes.clone());
    for i in (0..n).step_by(10_007) {
        assert_eq!(out[0][i], planes[0][i] + planes[1][i], "lane {i}");
    }
    let m = svc.metrics();
    assert!(m.launches >= 2, "expected a split, got {} launches", m.launches);
}

#[test]
fn mixed_ops_from_concurrent_clients() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = xla_service(dir);
    let wl = WorkloadGen::from_env("mixed_ops_concurrent");
    let mut joins = Vec::new();
    for t in 0..6u64 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let ops = [Op::Add, Op::Mul12, Op::Add22, Op::Mul22];
            for round in 0..10 {
                let op = ops[(t as usize + round) % ops.len()];
                let n = 500 + rng.below(5000);
                let planes = wl.planes(op, n, rng.next_u64());
                let out = h
                    .dispatch(Plan::new(op, planes.clone()).unwrap())
                    .unwrap()
                    .wait()
                    .unwrap();
                // spot check against native
                let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
                let mut native = vec![vec![0.0f32; n]; op.n_out()];
                ffgpu::ff::vector::dispatch(op.name(), &refs, &mut native).unwrap();
                for i in (0..n).step_by(131) {
                    assert_eq!(out[0][i].to_bits(), native[0][i].to_bits(),
                               "op={op} n={n} lane={i}");
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.requests, 60);
    assert_eq!(m.errors, 0);
}

#[test]
fn batching_coalesces_same_op_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = Service::start(ServiceSpec::uniform(xla_spec(dir), 1).with_max_batch(64))
        .unwrap();
    // submit many small async requests before the device thread drains
    let wl = WorkloadGen::from_env("batching_coalesces");
    let h = svc.handle();
    let mut pending = Vec::new();
    let mut wants = Vec::new();
    for k in 0..40 {
        let planes = wl.planes(Op::Add22, 50 + k, k as u64);
        wants.push(expect_add22(&planes));
        pending.push(h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap());
    }
    for (ticket, want) in pending.into_iter().zip(wants) {
        let out = ticket.wait().unwrap();
        for (i, (h_, l_)) in want.iter().enumerate() {
            assert_eq!((out[0][i], out[1][i]), (*h_, *l_), "lane {i}");
        }
    }
    let m = svc.metrics();
    assert!(
        m.batches < m.requests,
        "no coalescing happened: {} batches for {} requests",
        m.batches, m.requests
    );
}

#[test]
fn cpu_and_xla_backends_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = xla_service(dir);
    let cpu = Service::start(ServiceSpec::default()).unwrap();
    let wl = WorkloadGen::from_env("cpu_xla_agree");
    for op in [Op::Add12, Op::Mul12, Op::Add22, Op::Mul22, Op::Div22] {
        let planes = wl.planes(op, 3000, 0xE44E);
        let a = call(&xla, op, planes.clone());
        let b = call(&cpu, op, planes);
        for (pa, pb) in a.iter().zip(&b) {
            for i in 0..pa.len() {
                assert_eq!(pa[i].to_bits(), pb[i].to_bits(), "op={op} lane={i}");
            }
        }
    }
}

/// Tentpole acceptance: the live observatory's error bounds over a
/// mirrored canary stream must match the one-shot harness for nv35
/// within tolerance — the exact same input chunks stream through both
/// paths, so the intervals, means and max relative errors agree.
#[test]
fn live_observatory_matches_one_shot_nv35() {
    let total = 4096usize;
    let chunk = 1024usize;
    let seed = 0x0B5E;
    let svc = Service::start(
        ServiceSpec::uniform(BackendSpec::native_single(), 1).with_observatory(
            // exact-size mirror launches: the ladder adds padding, and
            // this test wants bit-for-bit the one-shot stream
            ObservatorySpec::new(1.0, ["nv35"]).with_ladder(vec![]),
        ),
    )
    .unwrap();
    let h = svc.handle();
    let ops = [Op::Add12, Op::Mul12, Op::Add22];
    for op in ops {
        for idx in 0..(total / chunk) as u64 {
            let planes = workload::planes_for(op.name(), chunk, seed ^ (idx << 20));
            h.dispatch_mirrored(Plan::new(op, planes).unwrap())
                .unwrap()
                .wait()
                .unwrap();
        }
    }
    let rep = svc.accuracy_report().expect("observatory armed");
    for op in ops {
        let one = one_shot_sweep("nv35", op, total, chunk, seed).unwrap();
        let live = rep
            .row("nv35", op)
            .unwrap_or_else(|| panic!("no live row for {op}"));
        assert_eq!(live.lanes, total as u64, "{op}");
        assert!(
            (live.max_ulp - one.max_ulp).abs() <= 1e-9,
            "{op}: live max {} vs one-shot {}",
            live.max_ulp,
            one.max_ulp
        );
        assert!(
            (live.min_ulp - one.min_ulp).abs() <= 1e-9,
            "{op}: live min {} vs one-shot {}",
            live.min_ulp,
            one.min_ulp
        );
        assert!(
            (live.mean_abs_ulp - one.mean_abs_ulp).abs() <= 1e-9,
            "{op}: live mean {} vs one-shot {}",
            live.mean_abs_ulp,
            one.mean_abs_ulp
        );
        assert!(
            (live.max_rel - one.max_rel).abs() <= 1e-30,
            "{op}: live rel {} vs one-shot {}",
            live.max_rel,
            one.max_rel
        );
    }
    // nv35's truncated adds must actually show error on add22 — a
    // trivially all-zero surface would make the equalities vacuous
    let add22 = rep.row("nv35", Op::Add22).unwrap();
    assert!(add22.max_ulp > 0.0 || add22.min_ulp < 0.0, "{add22:?}");
}

/// Tentpole acceptance: mirrored observation traffic must not perturb
/// measured routing. Mirrors execute on the observatory's own
/// backends, so the telemetry the `measured` policy routes over —
/// per-(shard, op) attempts/samples, queue depths — sees exactly the
/// client's requests and nothing else.
#[test]
fn observation_does_not_perturb_measured_routing() {
    let mk = || {
        ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::native_single(),
        ])
        .with_routing(Routing::Measured)
    };
    let plain = Service::start(mk()).unwrap();
    let observed = Service::start(
        mk().with_observatory(ObservatorySpec::new(1.0, ["nv35"])),
    )
    .unwrap();
    let wl = WorkloadGen::from_env("observation_no_perturb");
    let mut plain_picks = Vec::new();
    let mut observed_picks = Vec::new();
    for round in 0..8u64 {
        let planes = wl.planes(Op::Add22, 256, round);
        for (svc, picks) in [
            (&plain, &mut plain_picks),
            (&observed, &mut observed_picks),
        ] {
            let t = svc
                .handle()
                .dispatch(Plan::new(Op::Add22, planes.clone()).unwrap())
                .unwrap();
            picks.push(t.shard());
            t.wait().unwrap();
        }
    }
    // cold exploration is deterministic: identical request sequences
    // explore identically whether or not every request is mirrored
    assert_eq!(plain_picks[..2], observed_picks[..2]);
    for svc in [&plain, &observed] {
        // sequential waits mean one executed group per request; a
        // mirror that touched a shard would inflate these counters
        let view = svc.telemetry();
        let attempts: u64 = (0..svc.shards()).map(|s| view.attempts(s, Op::Add22)).sum();
        let samples: u64 = (0..svc.shards()).map(|s| view.samples(s, Op::Add22)).sum();
        assert_eq!(attempts, 8);
        assert_eq!(samples, 8);
        for s in 0..svc.shards() {
            assert_eq!(view.samples(s, Op::Mul22), 0, "phantom traffic on shard {s}");
        }
        assert_eq!(svc.metrics().requests, 8);
        assert_eq!(svc.handle().queue_depths(), vec![0, 0]);
    }
    // and the mirrors really ran: nv35 scored every request's lanes
    let rep = observed.accuracy_report().unwrap();
    assert_eq!(rep.mirrored_requests, 8);
    assert_eq!(rep.row("nv35", Op::Add22).unwrap().lanes, 8 * 256);
    assert!(plain.accuracy_report().is_none(), "no observatory on the plain set");
}

/// Tentpole acceptance: result-cache hits are invisible to routing
/// telemetry and to the observatory. With the cache armed, measured
/// routing on, and an observatory mirroring every sampled request, N
/// repeats of one grid must leave exactly one attempt/sample in shard
/// telemetry (so the rate EWMAs the `measured` policy scores over see
/// one execution, not N), one mirrored observatory request, and one
/// service-level request — the N-1 hits resolve before the sampler
/// tick and before routing, and never touch a shard.
#[test]
fn cache_hits_are_invisible_to_telemetry_and_observatory() {
    let svc = Service::start(
        ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::native_single(),
        ])
        .with_routing(Routing::Measured)
        .with_cache_mb(16)
        .with_observatory(ObservatorySpec::new(1.0, ["nv35"])),
    )
    .unwrap();
    let h = svc.handle();
    let planes = WorkloadGen::from_env("cache_invisible").planes(Op::Add22, 512, 0xCAFE);
    let rounds = 10u64;
    let mut first: Option<Vec<Vec<f32>>> = None;
    for _ in 0..rounds {
        let out = h
            .dispatch(Plan::new(Op::Add22, planes.clone()).unwrap())
            .unwrap()
            .wait()
            .unwrap();
        match &first {
            None => first = Some(out),
            // every hit is bit-identical to the cold execution
            Some(want) => {
                for (pw, po) in want.iter().zip(&out) {
                    for i in 0..pw.len() {
                        assert_eq!(pw[i].to_bits(), po[i].to_bits(), "lane {i}");
                    }
                }
            }
        }
    }
    // exactly one execution ever reached the shard layer
    let view = svc.telemetry();
    let attempts: u64 = (0..svc.shards()).map(|s| view.attempts(s, Op::Add22)).sum();
    let samples: u64 = (0..svc.shards()).map(|s| view.samples(s, Op::Add22)).sum();
    assert_eq!(attempts, 1, "cache hits fed routing attempt telemetry");
    assert_eq!(samples, 1, "cache hits fed a shard rate EWMA");
    assert_eq!(svc.metrics().requests, 1);
    let shard_reqs: u64 = svc.shard_metrics().iter().map(|s| s.requests).sum();
    assert_eq!(shard_reqs, 1, "a hit landed on a shard");
    assert_eq!(h.queue_depths(), vec![0, 0]);
    // the observatory mirrored exactly the one executed request
    let rep = svc.accuracy_report().unwrap();
    assert_eq!(rep.mirrored_requests, 1, "cache hits ticked the sampler");
    assert_eq!(rep.row("nv35", Op::Add22).unwrap().lanes, 512);
    // and the cache accounts for everything the shards never saw
    let cs = svc.cache_stats().unwrap();
    assert_eq!((cs.hits, cs.misses), (rounds - 1, 1));
    assert!(cs.live_bytes > 0);
}
