//! Trace codec property suite (ISSUE: trace record/replay).
//!
//! The codec's contract is *total and canonical*: `decode ∘ encode`
//! is the identity on every well-formed trace, equal traces encode to
//! equal bytes (flags are derived from content, never caller-chosen),
//! and every malformed byte stream fails with a typed [`TraceError`]
//! — never a panic, never an unbounded allocation. The malformed
//! corpus mirrors the wire-frame suite's approach: hand-corrupt one
//! field at a time at a known offset and pin the exact error variant.
//!
//! No proptest crate in the vendored set, so the round-trip property
//! runs as the repo's seeded random search (same substitution as
//! `backend_parity.rs`).

mod common;

use common::WorkloadGen;
use ffgpu::backend::Op;
use ffgpu::coordinator::{trace, Payload, Trace, TraceError, TraceRecord, Verdict};
use ffgpu::util::Rng;

/// A random well-formed record drawn from the full shape space:
/// every op, all three payload kinds, tenants from empty to 255
/// bytes (including multi-byte UTF-8), all classes and verdicts,
/// deadline/cancel fields spanning none / zero / finite.
fn random_record(rng: &mut Rng, wl: &WorkloadGen, case: u64) -> TraceRecord {
    let op = Op::ALL[rng.below(Op::COUNT)];
    let lanes = 1 + rng.below(96) as u32;
    let mut rec = match rng.below(3) {
        0 => TraceRecord::seeded(op, lanes, rng.next_u64()),
        1 => TraceRecord {
            lanes,
            payload: Payload::Fingerprint(rng.next_u64()),
            ..TraceRecord::seeded(op, lanes, 0)
        },
        _ => TraceRecord::inline(op, wl.planes(op, lanes as usize, case)),
    };
    rec = rec.at(rng.next_u64() >> 20);
    rec.class = [
        trace::CLASS_UNSPECIFIED,
        trace::CLASS_INTERACTIVE,
        trace::CLASS_STANDARD,
        trace::CLASS_BULK,
    ][rng.below(4)];
    rec.verdict = [
        Verdict::Unknown,
        Verdict::Ok,
        Verdict::DeadlineExceeded,
        Verdict::Cancelled,
        Verdict::Error,
    ][rng.below(5)];
    rec = match rng.below(3) {
        0 => rec,
        1 => rec.deadline_ns(0),
        _ => rec.deadline_ns(1 + (rng.next_u64() >> 32)),
    };
    if rng.below(4) == 0 {
        rec = rec.cancel_ns(rng.next_u64() >> 40);
    }
    let long = "x".repeat(255);
    let tenants = ["", "a", "alpha", "β-tenant-ü", long.as_str()];
    rec.tenant(tenants[rng.below(tenants.len())])
}

#[test]
fn prop_traces_round_trip_bit_identically() {
    let wl = WorkloadGen::from_env("trace_round_trip");
    let mut rng = Rng::new(0x72AC);
    for session in 0..60u64 {
        let n = rng.below(12);
        let records: Vec<TraceRecord> = (0..n)
            .map(|i| random_record(&mut rng, &wl, session * 64 + i as u64))
            .collect();
        let t = Trace::new(records);
        let bytes = t.encode();
        let back = Trace::decode(&bytes).expect("well-formed trace decodes");
        assert_eq!(back, t, "session {session}: decode ∘ encode != id");
        // canonical: re-encoding the decoded trace reproduces the bytes
        assert_eq!(back.encode(), bytes, "session {session}: bytes moved");
    }
}

#[test]
fn empty_trace_round_trips() {
    let t = Trace::default();
    let bytes = t.encode();
    assert_eq!(bytes.len(), 12, "header only");
    assert_eq!(Trace::decode(&bytes).unwrap(), t);
    assert!(!t.all_inline(), "vacuous all-inline must not set the flag");
}

#[test]
fn inline_flag_is_derived_from_content() {
    let all_inline = Trace::new(vec![
        TraceRecord::inline(Op::Add12, vec![vec![1.0; 4], vec![2.0; 4]]),
        TraceRecord::inline(Op::Mul, vec![vec![3.0; 2], vec![4.0; 2]]),
    ]);
    assert!(all_inline.all_inline());
    // flags live at header bytes 6..8 (little-endian u16)
    let bytes = all_inline.encode();
    assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), trace::FLAG_ALL_INLINE);
    let mixed = Trace::new(vec![
        TraceRecord::inline(Op::Add12, vec![vec![1.0; 4], vec![2.0; 4]]),
        TraceRecord::seeded(Op::Mul22, 8, 7),
    ]);
    assert!(!mixed.all_inline());
    let bytes = mixed.encode();
    assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
}

#[test]
fn save_load_round_trips_and_io_fails_typed() {
    let wl = WorkloadGen::from_env("trace_save_load");
    let mut rng = Rng::new(0x10AD);
    let records: Vec<TraceRecord> =
        (0..5).map(|i| random_record(&mut rng, &wl, i)).collect();
    let t = Trace::new(records);
    let path = std::env::temp_dir().join(format!(
        "ffgpu-trace-codec-{}.fftrace",
        std::process::id()
    ));
    t.save(&path).unwrap();
    assert_eq!(Trace::load(&path).unwrap(), t);
    std::fs::remove_file(&path).unwrap();
    assert!(matches!(Trace::load(&path), Err(TraceError::Io(_))));
}

/// One well-formed single-record trace whose field offsets are known
/// exactly (seeded payload, 2-byte tenant), for surgical corruption:
///
/// ```text
/// 0  magic      4  version    6  flags      8  count
/// 12 op         13 class      14 verdict    15 kind
/// 16 tenant_len 17 tenant[2]  19 arrival    27 deadline
/// 35 cancel     43 lanes      47 seed       55 end
/// ```
fn base_bytes() -> Vec<u8> {
    let t = Trace::new(vec![
        TraceRecord::seeded(Op::Mul22, 33, 0xFEED).tenant("ab").at(17)
    ]);
    let bytes = t.encode();
    assert_eq!(bytes.len(), 55);
    assert_eq!(Trace::decode(&bytes).unwrap(), t);
    bytes
}

/// The malformed corpus: one corruption per case, one typed error per
/// corruption. Every entry is a (mutate, expected-error) pair over the
/// known-good base trace.
#[test]
fn malformed_traces_fail_typed() {
    type Mutate = fn(&mut Vec<u8>);
    let corpus: Vec<(&str, Mutate, TraceError)> = vec![
        (
            "bad magic",
            |b| b[0] = b'X',
            TraceError::BadMagic,
        ),
        (
            "unknown version",
            |b| b[4] = 2,
            TraceError::BadVersion(2),
        ),
        (
            "unknown flag bits",
            |b| b[7] = 0x80,
            TraceError::BadFlags(0x8000),
        ),
        (
            "inline flag contradicting a seeded record",
            |b| b[6] = 1,
            TraceError::BadFlags(trace::FLAG_ALL_INLINE),
        ),
        (
            "op code outside the catalogue",
            |b| b[12] = Op::COUNT as u8,
            TraceError::BadOp(Op::COUNT as u8),
        ),
        (
            "class code outside the known set",
            |b| b[13] = 9,
            TraceError::BadClass(9),
        ),
        (
            "verdict code outside the known set",
            |b| b[14] = 9,
            TraceError::BadVerdict(9),
        ),
        (
            "payload kind outside the known set",
            |b| b[15] = 3,
            TraceError::BadPayloadKind(3),
        ),
        (
            "tenant bytes that are not UTF-8",
            |b| {
                b[17] = 0xFF;
                b[18] = 0xFE;
            },
            TraceError::BadTenant,
        ),
        (
            "zero lanes",
            |b| b[43..47].fill(0),
            TraceError::ZeroLanes,
        ),
        (
            "lanes beyond the allocation cap",
            |b| b[43..47].copy_from_slice(&u32::MAX.to_le_bytes()),
            TraceError::TooLarge { lanes: u32::MAX },
        ),
        (
            "trailing bytes after the last record",
            |b| b.extend_from_slice(&[0, 0, 0]),
            TraceError::TrailingBytes(3),
        ),
        (
            "count promising more records than the buffer holds",
            |b| b[8] = 2,
            TraceError::Truncated("op"),
        ),
        (
            "buffer cut mid-field",
            |b| b.truncate(50),
            TraceError::Truncated("seed"),
        ),
        (
            "buffer cut inside the header",
            |b| b.truncate(9),
            TraceError::Truncated("count"),
        ),
    ];
    for (what, mutate, want) in corpus {
        let mut bytes = base_bytes();
        mutate(&mut bytes);
        match Trace::decode(&bytes) {
            Err(e) => assert_eq!(e, want, "{what}: wrong error"),
            Ok(t) => panic!("{what}: decoded {} record(s) from corrupt bytes", t.records.len()),
        }
    }
}

/// Inline payloads carry their own arity hazard: a plane count that
/// disagrees with the op is unrepresentable after decode, and a lanes
/// field larger than the remaining buffer must fail before allocating.
#[test]
fn malformed_inline_payloads_fail_typed() {
    let t = Trace::new(vec![TraceRecord::inline(
        Op::Add12,
        vec![vec![1.5; 8], vec![2.5; 8]],
    )]);
    let good = t.encode();
    assert_eq!(Trace::decode(&good).unwrap(), t);
    // plane-count byte sits right after the lanes field: header 12 +
    // (4 fixed + 1 len + 0 tenant) + 24 ns fields + 4 lanes = 45
    let mut bad_arity = good.clone();
    assert_eq!(bad_arity[45], 2, "plane count byte");
    bad_arity[45] = 1;
    // one inline plane shorter than promised => arity first
    assert_eq!(
        Trace::decode(&bad_arity),
        Err(TraceError::ArityMismatch { op: Op::Add12, got: 1 })
    );
    // an honest arity but a lanes field bigger than the buffer: the
    // length check fires before any plane allocation happens
    let mut short = good;
    short[41..45].copy_from_slice(&1000u32.to_le_bytes());
    assert_eq!(Trace::decode(&short), Err(TraceError::Truncated("inline plane")));
}
