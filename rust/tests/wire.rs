//! Integration: the TCP wire front end end-to-end over loopback —
//! bit-identical outputs vs in-process dispatch, token-bucket
//! admission pushing back an over-quota client while others complete,
//! telemetry-driven shedding of hopeless deadlines, status/tenant
//! attribution, and a malformed-frame corpus the server must survive.

use ffgpu::backend::{BackendSpec, Op, ServiceError};
use ffgpu::coordinator::{Plan, Service, ServiceSpec};
use ffgpu::harness::workload;
use ffgpu::net::{
    encode_frame, read_frame, AdmissionConfig, ClassLimits, ClientClass, ClientHello,
    ErrorFrame, FrameKind, ShedPolicy, WireClient, WireConfig, WireError, WireServer,
};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A native service + wire server on an ephemeral loopback port.
/// Returned in drop order: server first, then the service it serves.
fn serve(cfg: WireConfig) -> (WireServer, Service, String) {
    let spec = ServiceSpec::uniform(BackendSpec::native(), 2);
    let svc = Service::start(spec).expect("service");
    let srv = WireServer::start(svc.handle(), "127.0.0.1:0", cfg).expect("wire listen");
    let addr = srv.local_addr().to_string();
    (srv, svc, addr)
}

#[test]
fn wire_outputs_are_bit_identical_to_in_process() {
    let (_srv, svc, addr) = serve(WireConfig::default());
    let mut cli = WireClient::connect(&addr, "parity", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    let h = svc.handle();
    for (case, &op) in [Op::Add22, Op::Mul22, Op::Mul12, Op::Add12, Op::Div22, Op::Mad22]
        .iter()
        .enumerate()
    {
        let n = 1000 + 513 * case;
        let planes = workload::planes_for(op.name(), n, 0xC0FFEE + case as u64);
        let local = h
            .dispatch(Plan::new(op, planes.clone()).expect("plan"))
            .expect("dispatch")
            .wait()
            .expect("local reply");
        let remote = cli.call(op, planes, None).expect("wire reply");
        assert_eq!(local.len(), remote.len(), "{op}: plane count");
        for (p, (a, b)) in local.iter().zip(&remote).enumerate() {
            assert_eq!(a.len(), b.len(), "{op}: plane {p} length");
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{op}: lane {i} of plane {p} differs"
                );
            }
        }
    }
}

#[test]
fn wire_pipelines_out_of_order_waits() {
    let (_srv, _svc, addr) = serve(WireConfig::default());
    let mut cli = WireClient::connect(&addr, "pipeline", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    // dispatch three, wait in reverse order: the stash must hold the
    // earlier replies until their ids are claimed
    let mut ids = Vec::new();
    let mut want = Vec::new();
    for k in 0..3u64 {
        let n = 2048 + 17 * k as usize;
        let planes = workload::planes_for(Op::Add22.name(), n, k);
        ids.push(cli.dispatch(Op::Add22, planes, None).expect("dispatch"));
        want.push(n);
    }
    for (&id, &n) in ids.iter().zip(&want).rev() {
        let out = cli.wait(id).expect("reply");
        assert_eq!(out[0].len(), n);
    }
}

#[test]
fn capped_client_sees_overloaded_while_uncapped_completes() {
    // a bulk class tight enough that the second submit trips the bucket
    let admission = AdmissionConfig::default().with_limits(
        ClientClass::Bulk,
        ClassLimits {
            lanes_per_sec: 1_000.0,
            burst_lanes: 5_000.0,
            max_inflight_bytes: 64 << 20,
        },
    );
    let cfg = WireConfig { admission, ..WireConfig::default() };
    let (_srv, svc, addr) = serve(cfg);

    let addr2 = addr.clone();
    let capped = std::thread::spawn(move || {
        let mut cli = WireClient::connect(&addr2, "hog", ClientClass::Bulk).expect("connect");
        cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
        let mut ok = 0u32;
        let mut overloaded = 0u32;
        for k in 0..4 {
            let planes = workload::planes_for(Op::Add22.name(), 4_000, k);
            match cli.call(Op::Add22, planes, None) {
                Ok(out) => {
                    assert_eq!(out[0].len(), 4_000);
                    ok += 1;
                }
                Err(WireError::Overloaded { retry_after_ms }) => {
                    assert!(retry_after_ms >= 1);
                    overloaded += 1;
                }
                Err(e) => panic!("hog: {e}"),
            }
        }
        (ok, overloaded)
    });

    let mut cli = WireClient::connect(&addr, "good", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    for k in 0..8 {
        let planes = workload::planes_for(Op::Mul22.name(), 4_000, 100 + k);
        let out = cli.call(Op::Mul22, planes, None).expect("standard reply");
        assert_eq!(out[0].len(), 4_000);
    }

    let (ok, overloaded) = capped.join().expect("capped client");
    assert!(ok >= 1, "first burst submit must be admitted");
    assert!(overloaded >= 1, "over-quota client must be pushed back");

    // attribution: pushback lands on the hog tenant, not the good one
    let tenants = svc.tenant_metrics();
    let hog = tenants.get("hog").expect("hog tenant recorded");
    assert!(hog.denied >= 1, "hog denials recorded: {hog:?}");
    let good = tenants.get("good").expect("good tenant recorded");
    assert_eq!(good.denied + good.shed, 0, "good tenant untouched: {good:?}");
    assert_eq!(good.requests, 8);
}

#[test]
fn hopeless_deadline_is_shed_from_telemetry() {
    // headroom scaled absurdly high: once telemetry warms, any
    // deadline-bearing request projects as hopeless and must be shed
    let cfg = WireConfig {
        shed: ShedPolicy { headroom: 1e9 },
        ..WireConfig::default()
    };
    let (_srv, svc, addr) = serve(cfg);
    let mut cli = WireClient::connect(&addr, "dead", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    // no deadline: never shed, and this warms the (shard, op) telemetry
    let planes = workload::planes_for(Op::Add22.name(), 8_192, 7);
    cli.call(Op::Add22, planes.clone(), None).expect("warmup");
    // telemetry may attribute the warmup to either shard; warm both by
    // repeating (routing is round-robin over two shards)
    cli.call(Op::Add22, planes.clone(), None).expect("warmup 2");
    match cli.call(Op::Add22, planes, Some(1)) {
        Err(WireError::Overloaded { retry_after_ms }) => assert!(retry_after_ms >= 1),
        other => panic!("expected shed, got {other:?}"),
    }
    let tenants = svc.tenant_metrics();
    assert!(tenants.get("dead").expect("tenant").shed >= 1);
}

#[test]
fn status_reports_shards_tiers_and_tenants() {
    let (_srv, _svc, addr) = serve(WireConfig::default());
    let mut cli = WireClient::connect(&addr, "status", ClientClass::Interactive)
        .expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    // hello already carries the shard set
    let hello = cli.server_hello().clone();
    assert_eq!(hello.shards.len(), 2);
    for s in &hello.shards {
        assert_eq!(s.label, "native");
        assert!(s.tier.is_some(), "native shards publish a kernel tier");
    }
    let planes = workload::planes_for(Op::Add12.name(), 1_024, 1);
    cli.call(Op::Add12, planes, None).expect("reply");
    let status = cli.status().expect("status");
    assert_eq!(status.shards.len(), 2);
    assert_eq!(status.queue_depths.len(), 2);
    let me = status
        .tenants
        .iter()
        .find(|t| t.tenant == "status")
        .expect("own tenant listed");
    assert_eq!(me.requests, 1);
    assert_eq!(me.lanes, 1_024);
}

#[test]
fn typed_errors_cross_the_wire() {
    let (_srv, _svc, addr) = serve(WireConfig::default());
    let mut cli = WireClient::connect(&addr, "errors", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    // ragged planes fail Plan validation server-side with the same
    // typed variant an in-process caller gets
    let planes = vec![vec![1.0f32; 8], vec![2.0f32; 8], vec![3.0f32; 7], vec![4.0f32; 8]];
    match cli.call(Op::Add22, planes, None) {
        Err(WireError::Remote(ServiceError::RaggedPlanes { op, plane, want, got })) => {
            assert_eq!(op, Op::Add22);
            assert_eq!(plane, 2);
            assert_eq!(got, 7);
            assert_eq!(want, 8);
        }
        other => panic!("expected RaggedPlanes, got {other:?}"),
    }
    // the connection survives a request-scoped error
    let ok = cli
        .call(Op::Add22, workload::planes_for(Op::Add22.name(), 64, 5), None)
        .expect("healthy after error");
    assert_eq!(ok[0].len(), 64);
}

/// Raw-socket malformed traffic: the server must answer with a typed
/// connection-level error (or just drop the connection) and keep
/// serving everyone else — never panic, never wedge.
#[test]
fn malformed_frames_never_kill_the_server() {
    let (_srv, _svc, addr) = serve(WireConfig::default());
    let corpus: Vec<Vec<u8>> = vec![
        b"GET / HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),          // wrong protocol
        vec![0xFF; 64],                                          // garbage
        {
            let mut f = encode_frame(FrameKind::ClientHello, b"{\"tenant\":\"x\"}");
            f[4] = 9; // wrong version
            f
        },
        {
            let mut f = encode_frame(FrameKind::ClientHello, &[]);
            f[5] = 0xEE; // unknown kind
            f
        },
        {
            let mut f = encode_frame(FrameKind::Submit, &[]);
            f[6..10].copy_from_slice(&u32::MAX.to_le_bytes()); // oversized decl
            f
        },
        encode_frame(FrameKind::Reply, b"{}"),                   // server-only kind
        encode_frame(FrameKind::ClientHello, b"not json"),       // bad control
        encode_frame(FrameKind::Submit, b"\x05\x00\x00\x00{...}"), // bad submit, no hello
        {
            let mut f = encode_frame(FrameKind::ClientHello, b"{\"tenant\":\"x\"}");
            f.truncate(f.len() - 3); // mid-frame disconnect
            f
        },
    ];
    for (i, bytes) in corpus.iter().enumerate() {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(bytes).expect("write");
        // half-close so the mid-frame case is a real disconnect, then
        // read until the server closes (typed error frame then EOF, or
        // plain EOF); a timeout here means the server wedged
        s.shutdown(std::net::Shutdown::Write).expect("shutdown");
        let mut sink = Vec::new();
        match s.read_to_end(&mut sink) {
            Ok(_) => {}
            Err(e) => panic!("case {i}: server wedged ({e})"),
        }
    }
    // after the whole corpus, a well-formed client still gets service
    let mut cli = WireClient::connect(&addr, "after", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    let out = cli
        .call(Op::Mul12, workload::planes_for(Op::Mul12.name(), 256, 9), None)
        .expect("server alive after corpus");
    assert_eq!(out[0].len(), 256);
}

/// A second ClientHello must not mint a fresh Admission (full token
/// bucket, zeroed in-flight budget) — that would let a rate-limited
/// client reset its quota after every denial. The server answers with
/// a connection-level protocol error and closes.
#[test]
fn duplicate_hello_is_a_protocol_error() {
    let (_srv, _svc, addr) = serve(WireConfig::default());
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let hello = ClientHello { tenant: "twice".into(), class: ClientClass::Standard };
    s.write_all(&encode_frame(FrameKind::ClientHello, &hello.encode())).expect("hello 1");
    let first = read_frame(&mut s).expect("read").expect("server hello");
    assert_eq!(first.kind, FrameKind::ServerHello);
    // the re-hello that would have laundered the rate limit away
    s.write_all(&encode_frame(FrameKind::ClientHello, &hello.encode())).expect("hello 2");
    let verdict = read_frame(&mut s).expect("read").expect("error frame");
    assert_eq!(verdict.kind, FrameKind::Error);
    let ef = ErrorFrame::decode(&verdict.payload).expect("decode");
    assert_eq!(ef.id, 0, "connection-level error");
    // ...and the connection is closed behind it
    assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
}

/// Connections beyond `max_conns` are refused with the same retryable
/// Overloaded signal as per-request pushback, not a hard error.
#[test]
fn over_capacity_connect_is_overloaded_with_retry_hint() {
    let cfg = WireConfig { max_conns: 1, ..WireConfig::default() };
    let (_srv, _svc, addr) = serve(cfg);
    // first connection holds the single slot (hello completed, so the
    // acceptor has definitely counted it)
    let mut holder =
        WireClient::connect(&addr, "holder", ClientClass::Standard).expect("connect");
    match WireClient::connect(&addr, "spill", ClientClass::Standard) {
        Err(WireError::Overloaded { retry_after_ms }) => assert!(retry_after_ms >= 1),
        other => panic!("expected Overloaded refusal, got {:?}", other.map(|_| ())),
    }
    // the admitted client is still healthy
    let out = holder
        .call(Op::Add22, workload::planes_for(Op::Add22.name(), 64, 2), None)
        .expect("holder reply");
    assert_eq!(out[0].len(), 64);
}

#[test]
fn submit_before_hello_is_a_protocol_error() {
    let (_srv, _svc, addr) = serve(WireConfig::default());
    let mut cli = WireClient::connect(&addr, "late", ClientClass::Standard).expect("connect");
    cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
    // a raw socket that submits without a hello gets a typed error
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let sub = ffgpu::net::Submit {
        id: 1,
        op: Op::Add22,
        deadline_ms: None,
        planes: workload::planes_for(Op::Add22.name(), 16, 0),
    };
    s.write_all(&encode_frame(FrameKind::Submit, &sub.encode())).expect("write");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("server answered then closed");
    assert!(!raw.is_empty(), "expected a connection-level error frame");
    // ... while the polite client on the same server still works
    let out = cli
        .call(Op::Add22, workload::planes_for(Op::Add22.name(), 128, 3), None)
        .expect("reply");
    assert_eq!(out[0].len(), 128);
}
