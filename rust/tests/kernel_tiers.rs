//! Kernel-tier parity and property suite (ISSUE: SIMD/FMA kernel tier).
//!
//! The tier engine's contract is *bit-parity on the servable domain*:
//! whatever tier a `NativeBackend` resolves to — scalar reference,
//! lane-blocked, or lane-blocked with FMA products — a served batch
//! returns the same bits. This file pins that contract end to end
//! (through `NativeBackend::execute`, serial and chunked-parallel),
//! plus the EFT property underneath it (Th. 3/4 of the paper:
//! `two_prod_fma` computes the same exact error as Dekker's 17-flop
//! `two_prod`), plus the *documented divergences* outside the
//! contract's domain (subnormal error terms, where Dekker's split-based
//! error underflows but the FMA error is still the correctly rounded
//! exact residue).
//!
//! `BlockedFma` correctness is exercised unconditionally: on hosts
//! without fast FMA `f32::mul_add` lowers to libm's `fmaf`, which is
//! slow but still correctly rounded, so the bit-parity claims hold
//! everywhere. Only *perf* commentary is gated on availability.

mod common;

use common::WorkloadGen;
use ffgpu::backend::{ExecJob, KernelTier, NativeBackend, Op};
use ffgpu::ff::{two_prod, two_prod_fma};
use ffgpu::util::Rng;

/// Every op the native backend serves.
const OPS: [Op; 10] = Op::ALL;

fn run_backend(
    be: &mut NativeBackend, wl: &WorkloadGen, op: Op, n: usize, case: u64,
) -> Vec<Vec<f32>> {
    let planes = wl.planes(op, n, case);
    let job = ExecJob::new(op, planes).unwrap();
    let mut outs = vec![vec![0.0f32; n]; op.n_out()];
    be.execute(&job, &mut outs).unwrap();
    outs
}

fn assert_bitwise(a: &[Vec<f32>], b: &[Vec<f32>], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: plane count");
    for (pi, (pa, pb)) in a.iter().zip(b).enumerate() {
        for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: plane {pi} lane {i}: {x:?} vs {y:?}"
            );
        }
    }
}

/// Every servable op, every tier, through the serial path (chunk > n)
/// AND the chunked 4-worker crew — all bit-identical to the scalar
/// single-worker reference. Sizes straddle lane (8) and chunk (1024)
/// boundaries so blocked main loops, scalar tails and chunk seams are
/// all on the hook.
#[test]
fn every_tier_matches_scalar_through_the_backend() {
    let sizes = [1usize, 7, 8, 9, 1023, 1024, 1025, 5000];
    let wl = WorkloadGen::from_env("every_tier_matches_scalar");
    let mut reference = NativeBackend::with_tier(1 << 20, 1, Some(KernelTier::Scalar));
    for tier in [KernelTier::Blocked, KernelTier::BlockedFma] {
        if tier == KernelTier::BlockedFma && !tier.available() {
            eprintln!("(blocked-fma has no fast path on this host/build; \
                       correctness still checked via libm fmaf)");
        }
        let mut serial = NativeBackend::with_tier(1 << 20, 1, Some(tier));
        let mut chunked = NativeBackend::with_tier(1024, 4, Some(tier));
        assert_eq!(serial.tier(), tier);
        for op in OPS {
            for &n in &sizes {
                let case = 0x7133 ^ (n as u64);
                let want = run_backend(&mut reference, &wl, op, n, case);
                let got = run_backend(&mut serial, &wl, op, n, case);
                assert_bitwise(&want, &got, &format!("{tier}/serial {op} n={n}"));
                let got = run_backend(&mut chunked, &wl, op, n, case);
                assert_bitwise(&want, &got, &format!("{tier}/chunked {op} n={n}"));
            }
        }
    }
}

/// The auto-resolved tier (whatever this host detects) also matches
/// the scalar reference — the configuration every real serving path
/// actually runs.
#[test]
fn detected_tier_matches_scalar() {
    let detected = KernelTier::detect();
    let wl = WorkloadGen::from_env("detected_tier_matches_scalar");
    let mut reference = NativeBackend::with_tier(1 << 20, 1, Some(KernelTier::Scalar));
    let mut auto = NativeBackend::with_tier(2048, 4, Some(detected));
    for op in OPS {
        let want = run_backend(&mut reference, &wl, op, 4096, 0xD7C7);
        let got = run_backend(&mut auto, &wl, op, 4096, 0xD7C7);
        assert_bitwise(&want, &got, &format!("detected {detected} {op}"));
    }
}

/// Paper Th. 3/4 as a property: over the entire range where Dekker's
/// split does not overflow and the product's error term does not
/// underflow, `two_prod_fma` is bit-identical to the 17-flop Dekker
/// `two_prod` — the exactness that licenses the BlockedFma tier.
#[test]
fn two_prod_fma_is_bit_identical_to_dekker_in_range() {
    let mut rng = Rng::new(0xF3A);
    let mut checked = 0u64;
    for _ in 0..200_000 {
        // |a·b| in ~[2^-60, 2^60]: products and error terms stay
        // comfortably normal, splits stay far from overflow
        let a = rng.spread_f32(-30, 30);
        let b = rng.spread_f32(-30, 30);
        let (x, y) = two_prod(a, b);
        let (xf, yf) = two_prod_fma(a, b);
        assert_eq!(x.to_bits(), xf.to_bits(), "hi differs for {a:?}*{b:?}");
        assert_eq!(y.to_bits(), yf.to_bits(), "lo differs for {a:?}*{b:?}");
        // and both are the exact product (representable in f64)
        let exact = f64::from(a) * f64::from(b);
        assert_eq!(f64::from(x) + f64::from(y), exact, "{a:?}*{b:?}");
        checked += 1;
    }
    assert_eq!(checked, 200_000);
}

/// Documented divergence: when the product's error term is subnormal,
/// Dekker's split-based residue can flush differently, but the FMA
/// form still returns the *correctly rounded* exact residue
/// `fl(a·b − x)` — which here is exactly `(a₆₄·b₆₄ − x₆₄)` rounded to
/// f32, since the residue is representable in f64. The hi words always
/// agree (both are `fl(a·b)`).
#[test]
fn subnormal_error_terms_diverge_as_documented() {
    let mut rng = Rng::new(0x5AB);
    let mut dekker_divergences = 0u64;
    for _ in 0..100_000 {
        let a = rng.spread_f32(-8, 8);
        let b = rng.spread_f32(-140, -120); // error term lands subnormal
        let (x, y) = two_prod(a, b);
        let (xf, yf) = two_prod_fma(a, b);
        assert_eq!(x.to_bits(), xf.to_bits(), "hi must agree for {a:?}*{b:?}");
        // the FMA residue is the correctly rounded exact error
        let exact_err = (f64::from(a) * f64::from(b) - f64::from(x)) as f32;
        assert_eq!(
            yf.to_bits(),
            exact_err.to_bits(),
            "fma residue must be correctly rounded for {a:?}*{b:?}"
        );
        if y.to_bits() != yf.to_bits() {
            dekker_divergences += 1;
        }
    }
    // the divergence is real on this domain (if Dekker agreed
    // everywhere the "documented divergence" table would be empty);
    // it is also not total — plenty of error terms still round the
    // same way
    println!("dekker-vs-fma subnormal divergences: {dekker_divergences}/100000");
}

/// The tier engine's dispatch surface rejects unknown ops and reports
/// availability coherently.
#[test]
fn tier_surface_is_coherent() {
    assert!(KernelTier::Scalar.available());
    assert!(KernelTier::Blocked.available());
    // detect() never picks an unavailable tier and never the scalar
    // fallback (blocked is always at least as good)
    let d = KernelTier::detect();
    assert!(d.available());
    assert_ne!(d, KernelTier::Scalar);
    // parse round-trips every canonical name
    for t in KernelTier::ALL {
        assert_eq!(KernelTier::parse(t.name()).unwrap(), t);
    }
    assert!(KernelTier::parse("warp-speed").is_err());
}
