//! Shared test-support: one seeded workload generator for every
//! integration test and bench.
//!
//! Before this module existed, four call sites
//! (`integration_coordinator.rs`, `backend_parity.rs`,
//! `kernel_tiers.rs`, `benches/coordinator.rs`) each rolled their own
//! plane-filling loop with hand-picked magic seeds. A parity failure in
//! one file could not be reproduced from another because the fill
//! recipes diverged. Now everything funnels through [`WorkloadGen`]:
//! a SplitMix64 stream keyed by one session seed, printed at
//! construction so any failing run can be replayed exactly with
//! `FFGPU_TEST_SEED=<seed> cargo test ...`.
//!
//! Benches include this file by path
//! (`#[path = "../tests/common/mod.rs"] mod common;`), so the recipe is
//! literally the same code in both worlds.

// Each test binary includes this module separately and uses a
// different slice of it — silence per-binary dead-code noise.
#![allow(dead_code)]

use ffgpu::backend::Op;
use ffgpu::harness::workload;

/// Default session seed — any fixed odd-ish constant works; this one
/// spells "f f g p u" on a phone keypad, give or take.
pub const DEFAULT_SEED: u64 = 0x1FF6_7085_F0CE_ED01;

/// SplitMix64: the canonical 64-bit mix (Steele et al.). Tiny state,
/// full-period, and — crucially — *splittable*: `gen.sub(case)`
/// derives an independent stream per test case, so adding a case never
/// shifts the values any other case sees.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seeded workload generator. Construct once per test via
/// [`WorkloadGen::from_env`]; derive per-case seeds with
/// [`WorkloadGen::sub`]; materialise operand planes with
/// [`WorkloadGen::planes`].
#[derive(Clone, Copy, Debug)]
pub struct WorkloadGen {
    seed: u64,
}

impl WorkloadGen {
    /// Generator over an explicit seed.
    pub fn new(seed: u64) -> WorkloadGen {
        WorkloadGen { seed }
    }

    /// Generator seeded from `FFGPU_TEST_SEED` (decimal or `0x` hex)
    /// when set, else [`DEFAULT_SEED`]. Prints the seed so a failing
    /// CI log always carries the reproduction recipe.
    pub fn from_env(label: &str) -> WorkloadGen {
        let seed = std::env::var("FFGPU_TEST_SEED")
            .ok()
            .and_then(|s| {
                let s = s.trim();
                if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                    u64::from_str_radix(hex, 16).ok()
                } else {
                    s.parse().ok()
                }
            })
            .unwrap_or(DEFAULT_SEED);
        println!("[{label}] workload seed: {seed:#018x} (override: FFGPU_TEST_SEED)");
        WorkloadGen { seed }
    }

    /// The session seed this generator runs on.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Derived per-case seed: an independent SplitMix64 draw keyed by
    /// `(session seed, case)`. Stable under reordering of other cases.
    pub fn sub(&self, case: u64) -> u64 {
        let mut s = self.seed ^ case.wrapping_mul(0xA24B_AED4_963E_E407);
        splitmix64(&mut s)
    }

    /// `op.n_in()` operand planes of `n` lanes for case `case`, via the
    /// shared [`workload::planes_for`] recipe (float-float pairs
    /// normalised, `div22` divisors bounded away from zero).
    pub fn planes(&self, op: Op, n: usize, case: u64) -> Vec<Vec<f32>> {
        workload::planes_for(op.name(), n, self.sub(case))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_streams_are_independent_and_stable() {
        let g = WorkloadGen::new(42);
        assert_eq!(g.sub(0), WorkloadGen::new(42).sub(0));
        assert_ne!(g.sub(0), g.sub(1));
        assert_ne!(g.sub(1), WorkloadGen::new(43).sub(1));
    }

    #[test]
    fn planes_match_shared_recipe() {
        let g = WorkloadGen::new(7);
        let p = g.planes(Op::Add22, 16, 3);
        assert_eq!(p.len(), Op::Add22.n_in());
        assert!(p.iter().all(|pl| pl.len() == 16));
        assert_eq!(p, workload::planes_for("add22", 16, g.sub(3)));
    }
}
