//! Replay harness acceptance suite (ISSUE: trace record/replay).
//!
//! Three contracts, end to end through a live [`Service`]:
//!
//! 1. **Lifecycle fidelity** — deadline-bearing and cancelled requests
//!    replayed at 4× produce the verdicts the trace recorded
//!    (Ok / DeadlineExceeded / Cancelled): zero-deadline records are
//!    deterministically triaged before any shard sees them, and
//!    zero-offset cancels win before the wait starts, so speed cannot
//!    flip an outcome.
//! 2. **Determinism** — replaying one trace twice on one configuration
//!    yields identical results checksums and identical per-op
//!    request/verdict/lane counts ([`ReplayReport::determinism_key`]).
//! 3. **Invisibility** — arming a [`TraceRecorder`] changes nothing a
//!    client or the telemetry plane can observe: same reply bits, same
//!    request/element counters, same per-shard placement, same
//!    observatory mirror counts; the only difference is the captured
//!    trace itself.

mod common;

use common::WorkloadGen;
use ffgpu::backend::{BackendSpec, Op};
use ffgpu::coordinator::{
    replay, ObservatorySpec, Plan, Routing, Service, ServiceSpec, Trace, TraceRecord,
    TraceRecorder, Verdict,
};
use std::sync::Arc;

fn native_service(shards: usize) -> Service {
    Service::start(ServiceSpec::uniform(BackendSpec::native(), shards)).unwrap()
}

/// Recorded-verdict counts per op, from the trace itself.
fn expected_counts(trace: &Trace, op: Op) -> (u64, u64, u64, u64) {
    let mut c = (0u64, 0u64, 0u64, 0u64);
    for r in trace.records.iter().filter(|r| r.op == op) {
        c.0 += 1;
        match r.verdict {
            Verdict::DeadlineExceeded => c.2 += 1,
            Verdict::Cancelled => c.3 += 1,
            _ => c.1 += 1,
        }
    }
    c
}

/// Satellite: the ticket lifecycle under replay. A trace holding an
/// ordinary request, a deliberate deadline miss (0 ns deadline), an
/// abandoned request (0 ns cancel offset) and two more Ok requests
/// replays at 4× with every verdict matching the recorded outcome.
#[test]
fn lifecycle_verdicts_replay_as_recorded() {
    let trace = Trace::new(vec![
        TraceRecord::seeded(Op::Add22, 2048, 0xA1)
            .tenant("alpha")
            .at(0)
            .deadline_ns(5_000_000_000)
            .verdict(Verdict::Ok),
        TraceRecord::seeded(Op::Mul22, 2048, 0xA2)
            .tenant("beta")
            .at(10_000_000)
            .deadline_ns(0)
            .verdict(Verdict::DeadlineExceeded),
        TraceRecord::seeded(Op::Div22, 1024, 0xA3)
            .tenant("alpha")
            .at(20_000_000)
            .cancel_ns(0)
            .verdict(Verdict::Cancelled),
        TraceRecord::seeded(Op::Add22, 512, 0xA4).tenant("beta").at(30_000_000),
        TraceRecord::seeded(Op::Mad22, 777, 0xA5)
            .tenant("alpha")
            .at(40_000_000)
            .deadline_ns(5_000_000_000)
            .verdict(Verdict::Ok),
    ]);
    let svc = native_service(2);
    let report = replay(&svc, &trace, 4.0).unwrap();
    assert_eq!(report.records, trace.records.len());
    assert_eq!(report.rate, 4.0);
    for op in [Op::Add22, Op::Mul22, Op::Div22, Op::Mad22] {
        let (req, ok, dl, cancel) = expected_counts(&trace, op);
        let row = report
            .per_op
            .iter()
            .find(|r| r.op == op.name())
            .unwrap_or_else(|| panic!("no replay row for {op}"));
        assert_eq!(
            (row.requests, row.ok, row.deadline_exceeded, row.cancelled, row.errors),
            (req, ok, dl, cancel, 0),
            "verdicts for {op} diverge from the recorded lifecycle"
        );
    }
    // the virtual clock actually compressed: 40 ms of recorded arrivals
    // at 4x is 10 ms of pacing, and the report knows the virtual span
    assert_eq!(report.virtual_s, 0.04);
    assert!(report.wall_s >= 0.01, "pacing skipped: wall {}s", report.wall_s);
}

/// Acceptance: same trace + same configuration, replayed twice =>
/// identical results checksum and identical per-op counts. The
/// determinism key folds both, so one equality pins the whole claim —
/// the per-row comparison below is the diagnostic form.
#[test]
fn replaying_twice_is_deterministic() {
    let wl = WorkloadGen::from_env("replay_deterministic");
    let ops = [Op::Add22, Op::Mul22, Op::Mul12, Op::Add12, Op::Div22, Op::Mad22];
    let mut records: Vec<TraceRecord> = (0..12u64)
        .map(|i| {
            TraceRecord::seeded(ops[i as usize % ops.len()], 256 + 37 * i as u32, wl.sub(i))
                .tenant(if i % 2 == 0 { "alpha" } else { "beta" })
                .at(i * 2_000_000)
        })
        .collect();
    records[5] = records[5].clone().deadline_ns(0).verdict(Verdict::DeadlineExceeded);
    records[9] = records[9].clone().cancel_ns(0).verdict(Verdict::Cancelled);
    let trace = Trace::new(records);

    let run = || {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native(), 2).with_routing(Routing::Measured),
        )
        .unwrap();
        replay(&svc, &trace, 32.0).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.results_fnv, b.results_fnv, "results checksum moved between replays");
    assert_eq!(a.determinism_key(), b.determinism_key(), "determinism key moved");
    assert_eq!(a.per_op.len(), b.per_op.len());
    for (ra, rb) in a.per_op.iter().zip(&b.per_op) {
        assert_eq!(
            (ra.op, ra.requests, ra.ok, ra.deadline_exceeded, ra.cancelled, ra.errors, ra.lanes),
            (rb.op, rb.requests, rb.ok, rb.deadline_exceeded, rb.cancelled, rb.errors, rb.lanes),
        );
    }
}

/// Acceptance: recording is invisible. The same serial workload runs
/// through two identically configured services — one with a recorder
/// armed — and every observable surface matches: reply bits, service
/// counters, per-shard placement, observatory mirror counts. The
/// recorder meanwhile captured exactly the dispatched traffic.
#[test]
fn recording_is_invisible_to_telemetry_and_observatory() {
    let wl = WorkloadGen::from_env("recorder_invisible");
    let obs = || ObservatorySpec::from_cli("1.0", "ieee-rn").unwrap();
    let plain = Service::start(
        ServiceSpec::uniform(BackendSpec::native(), 2).with_observatory(obs()),
    )
    .unwrap();
    let rec = Arc::new(TraceRecorder::new(1 << 20, false));
    let recorded = Service::start(
        ServiceSpec::uniform(BackendSpec::native(), 2)
            .with_observatory(obs())
            .with_recorder(Arc::clone(&rec)),
    )
    .unwrap();

    let ops = [Op::Add22, Op::Mul22, Op::Div22, Op::Add12, Op::Mul12];
    let mut replies = Vec::new();
    for (svc, label) in [(&plain, "plain"), (&recorded, "recorded")] {
        let mut outs = Vec::new();
        for case in 0..10u64 {
            let op = ops[case as usize % ops.len()];
            let planes = wl.planes(op, 300 + 11 * case as usize, case);
            let out = svc
                .handle()
                .dispatch(Plan::new(op, planes).unwrap())
                .unwrap()
                .wait()
                .unwrap_or_else(|e| panic!("{label} reply: {e}"));
            outs.push(out);
        }
        replies.push(outs);
    }
    // same bits out, with and without the recorder in the path
    assert_eq!(replies[0], replies[1], "recorder changed reply bits");

    // same service counters and the same per-shard placement (serial
    // round-robin dispatch is deterministic)
    let (mp, mr) = (plain.metrics(), recorded.metrics());
    assert_eq!(mp.requests, mr.requests);
    assert_eq!(mp.elements, mr.elements);
    assert_eq!(mp.errors, mr.errors);
    let (sp, sr) = (plain.shard_metrics(), recorded.shard_metrics());
    for (i, (a, b)) in sp.iter().zip(&sr).enumerate() {
        assert_eq!(a.requests, b.requests, "shard {i} placement moved");
        assert_eq!(a.elements, b.elements, "shard {i} elements moved");
    }

    // the observatory saw exactly as much traffic either way (fraction
    // 1.0 samples every request; sent + backpressure-dropped is exact)
    let (op_, or_) = (
        plain.accuracy_report().expect("observatory armed"),
        recorded.accuracy_report().expect("observatory armed"),
    );
    assert_eq!(
        op_.mirrored_requests + op_.dropped_requests,
        or_.mirrored_requests + or_.dropped_requests,
        "recorder perturbed the observatory sampler"
    );

    // and the capture itself is complete and well-formed
    assert_eq!(rec.len(), 10, "recorder missed traffic");
    assert_eq!(rec.dropped(), 0);
    let trace = rec.trace();
    assert_eq!(Trace::decode(&trace.encode()).unwrap(), trace);
    for (case, r) in trace.records.iter().enumerate() {
        assert_eq!(r.op, ops[case % ops.len()]);
        assert_eq!(r.lanes as usize, 300 + 11 * case);
        assert_eq!(r.verdict, Verdict::Unknown, "live captures cannot see the future");
    }
}
