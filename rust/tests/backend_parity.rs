//! Cross-backend parity: Native, GpuSim-in-IEEE-mode and (when
//! artifacts exist) XLA must produce **bit-identical** planes for the
//! EFT operators on random batches.
//!
//! No proptest crate in the vendored set, so this is the repo's seeded
//! random-search harness (same substitution as `prop_invariants.rs`):
//! each case draws an operator, a batch size and a seed, runs every
//! available backend through the *same* `KernelBackend` interface, and
//! compares against the native reference lane by lane.

mod common;

use common::WorkloadGen;
use ffgpu::backend::{
    BackendSpec, ExecJob, KernelBackend, KernelTier, NativeBackend, Op, ServiceError,
};
use ffgpu::util::Rng;
use std::path::PathBuf;

/// Ops whose outputs are bit-identical across substrates (EFT chains:
/// every operation individually rounded, identical operation order).
/// `split` (mask vs Dekker) and `div22` (hardware divide vs reciprocal)
/// are numerically equivalent but not bit-equal by design.
const PARITY_OPS: [Op; 5] = [Op::Add22, Op::Mul22, Op::Mul12, Op::Add12, Op::Mad22];

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

/// Every backend that can be built in this environment, with a label.
/// The native entries pin tiers explicitly: a blocked 4-worker crew
/// always, plus the FMA tier (libm-lowered where the host has no fast
/// FMA — slow but identical bits, so parity still holds).
fn backends() -> Vec<(String, Box<dyn KernelBackend>)> {
    let mut v: Vec<(String, Box<dyn KernelBackend>)> = vec![
        (
            "native-parallel".to_string(),
            Box::new(NativeBackend::new(2048, 4)),
        ),
        (
            "native-blocked".to_string(),
            Box::new(NativeBackend::with_tier(2048, 4, Some(KernelTier::Blocked))),
        ),
        (
            "native-blocked-fma".to_string(),
            Box::new(NativeBackend::with_tier(2048, 4, Some(KernelTier::BlockedFma))),
        ),
        (
            "gpusim-ieee".to_string(),
            BackendSpec::gpusim_ieee().build().unwrap(),
        ),
    ];
    if !KernelTier::BlockedFma.available() {
        eprintln!("(note: blocked-fma runs via libm fmaf on this host/build)");
    }
    if let Some(dir) = artifacts_dir() {
        match (BackendSpec::Xla { artifacts: dir, precompile: false }).build() {
            Ok(b) => v.push(("xla".to_string(), b)),
            Err(e) => eprintln!("skipping xla backend: {e}"),
        }
    } else {
        eprintln!("skipping xla backend: no artifacts (run `make artifacts`)");
    }
    v
}

fn execute(
    b: &mut dyn KernelBackend, op: Op, planes: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>, ServiceError> {
    let n = planes[0].len();
    let job = ExecJob::new(op, planes.to_vec())?;
    let mut outs = vec![vec![0.0f32; n]; op.n_out()];
    b.execute(&job, &mut outs)?;
    Ok(outs)
}

#[test]
fn prop_backends_bit_match_native_on_random_batches() {
    // reference: the seed's serving semantics — single-threaded native
    // on the scalar tier, pinned explicitly so env/detection can't
    // move the goalposts
    let mut reference = NativeBackend::with_tier(1 << 20, 1, Some(KernelTier::Scalar));
    let mut others = backends();
    let wl = WorkloadGen::from_env("backend_parity");
    let mut rng = Rng::new(0xBAC7);
    let cases = 60;
    for case in 0..cases {
        let op = PARITY_OPS[rng.below(PARITY_OPS.len())];
        // sizes straddle the native chunking threshold and stay odd
        let n = 1 + rng.below(9000);
        let planes = wl.planes(op, n, 0x9000 + case as u64);
        let want = execute(&mut reference, op, &planes).unwrap();
        for (label, b) in others.iter_mut() {
            let got = execute(b.as_mut(), op, &planes).unwrap();
            assert_eq!(got.len(), want.len(), "case {case}: {label} {op}");
            for (o, (pg, pw)) in got.iter().zip(&want).enumerate() {
                for i in 0..n {
                    assert_eq!(
                        pg[i].to_bits(),
                        pw[i].to_bits(),
                        "case {case}: backend={label} op={op} n={n} out{o} lane {i}: \
                         got {} want {}",
                        pg[i],
                        pw[i]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_div22_agrees_within_tolerance_across_backends() {
    // div22 is recip-based on the stream VM — equivalent accuracy
    // class, not bit-equal; pin the tolerance so regressions surface.
    let mut reference = NativeBackend::with_tier(1 << 20, 1, Some(KernelTier::Scalar));
    let mut sim = BackendSpec::gpusim_ieee().build().unwrap();
    let wl = WorkloadGen::from_env("div22_tolerance");
    let mut rng = Rng::new(0xD1F2);
    for case in 0..20 {
        let n = 1 + rng.below(2000);
        let planes = wl.planes(Op::Div22, n, 0x7000 + case as u64);
        let want = execute(&mut reference, Op::Div22, &planes).unwrap();
        let got = execute(sim.as_mut(), Op::Div22, &planes).unwrap();
        for i in 0..n {
            let w = want[0][i] as f64 + want[1][i] as f64;
            let g = got[0][i] as f64 + got[1][i] as f64;
            let rel = if w == 0.0 { g.abs() } else { ((g - w) / w).abs() };
            assert!(rel < 2f64.powi(-38), "case {case} lane {i}: rel={rel:e}");
        }
    }
}

#[test]
fn backends_expose_consistent_catalogs() {
    for (label, b) in backends().iter() {
        for op in PARITY_OPS {
            assert!(b.supports(op), "{label} missing {op}");
        }
        // typed catalogues cannot contain unknown ops by construction;
        // pin that they stay within the canonical set and unduplicated
        let ops = b.ops();
        for op in &ops {
            assert!(Op::ALL.contains(op), "{label} serves {op}");
        }
        let dedup: std::collections::HashSet<Op> = ops.iter().copied().collect();
        assert_eq!(dedup.len(), ops.len(), "{label} lists duplicates");
    }
}

#[test]
fn backend_errors_are_typed_uniformly() {
    // unknown names die at the parse boundary, before any backend runs
    assert!(matches!(
        Op::parse("frobnicate"),
        Err(ServiceError::UnknownOp(_))
    ));
    // input-shape errors die at ExecJob construction — a malformed job
    // is unrepresentable, so no backend can even see one
    let a = vec![1.0f32; 8];
    assert!(matches!(
        ExecJob::new(Op::Add22, vec![a.clone(), a.clone()]),
        Err(ServiceError::Arity { .. })
    ));
    assert!(matches!(
        ExecJob::new(Op::Add, vec![a.clone(), vec![1.0f32; 4]]),
        Err(ServiceError::RaggedPlanes { plane: 1, .. })
    ));
    assert!(matches!(
        ExecJob::new(Op::Add, vec![vec![], vec![]]),
        Err(ServiceError::EmptyBatch { op: Op::Add })
    ));
    // output-buffer mismatches are still every backend's own check
    let mut backends = backends();
    for (label, b) in backends.iter_mut() {
        let job = ExecJob::new(Op::Add, vec![a.clone(), a.clone()]).unwrap();
        let mut wrong_count = vec![vec![0.0f32; 8]; 2];
        assert!(
            matches!(
                b.execute(&job, &mut wrong_count),
                Err(ServiceError::Shape(_))
            ),
            "{label}"
        );
        let mut wrong_len = vec![vec![0.0f32; 3]];
        assert!(
            matches!(b.execute(&job, &mut wrong_len), Err(ServiceError::Shape(_))),
            "{label}"
        );
    }
}

/// The acceptance property behind the sharded tentpole: the same batch
/// served through a sharded native service matches the single-shard
/// answer bit-for-bit (sharding only changes *where* kernels run).
#[test]
fn sharded_service_matches_single_shard_bitwise() {
    use ffgpu::coordinator::{Plan, Service, ServiceSpec};
    let single = Service::start(
        ServiceSpec::uniform(BackendSpec::native_single(), 1).with_max_batch(32),
    )
    .unwrap();
    let sharded = Service::start(
        ServiceSpec::uniform(BackendSpec::native(), 4).with_max_batch(32),
    )
    .unwrap();
    let wl = WorkloadGen::from_env("sharded_bitwise");
    let mut rng = Rng::new(0x54A2);
    for round in 0..12 {
        let op = PARITY_OPS[rng.below(PARITY_OPS.len())];
        let n = 100 + rng.below(20_000);
        let planes = wl.planes(op, n, round);
        let a = single
            .handle()
            .dispatch(Plan::new(op, planes.clone()).unwrap())
            .unwrap()
            .wait()
            .unwrap();
        let b = sharded
            .handle()
            .dispatch(Plan::new(op, planes).unwrap())
            .unwrap()
            .wait()
            .unwrap();
        for (pa, pb) in a.iter().zip(&b) {
            for i in 0..n {
                assert_eq!(
                    pa[i].to_bits(),
                    pb[i].to_bits(),
                    "round {round} op={op} lane {i}"
                );
            }
        }
    }
}
