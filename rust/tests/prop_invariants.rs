//! Property-based invariant tests.
//!
//! No proptest crate in the vendored set, so this is a seeded
//! random-search harness (documented substitution, DESIGN.md): each
//! property runs tens of thousands of cases drawn from adversarial
//! distributions (wide exponent spreads, near-cancellation pairs,
//! boundary mantissas) and reports the first counterexample verbatim.

use ffgpu::coordinator::batcher;
use ffgpu::ff::{self, FF32};
use ffgpu::gpusim::{algorithms as sim, GpuModel};
use ffgpu::mp::{BigUint, Dyadic};
use ffgpu::util::Rng;

const CASES: usize = 50_000;

/// Adversarial f32 generator: spreads, exact powers, boundary mantissas,
/// near-cancellation partners.
fn adversarial_f32(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => rng.spread_f32(-80, 80),
        1 => rng.spread_f32(-3, 3),
        2 => (rng.uniform(-40.0, 40.0)).exp2() as f32, // exact powers of 2
        3 => {
            // all-ones mantissa
            let e = rng.uniform(-20.0, 20.0).exp2() as f32;
            e * (2.0 - f32::EPSILON)
        }
        4 => {
            // mantissa with only the last bit set beyond 1.0
            let e = rng.uniform(-20.0, 20.0).exp2() as f32;
            e * (1.0 + f32::EPSILON)
        }
        5 => -rng.spread_f32(-10, 10),
        6 => rng.spread_f32(-126, -100), // near the flush boundary
        _ => rng.spread_f32(0, 30),
    }
}

#[test]
fn prop_two_sum_is_error_free() {
    let mut rng = Rng::new(0x1001);
    for case in 0..CASES {
        let a = adversarial_f32(&mut rng);
        let b = adversarial_f32(&mut rng);
        let (s, r) = ff::two_sum(a, b);
        if !s.is_finite() {
            continue;
        }
        assert_eq!(
            s as f64 + r as f64,
            a as f64 + b as f64,
            "case {case}: two_sum({a:e}, {b:e}) = ({s:e}, {r:e})"
        );
    }
}

#[test]
fn prop_two_prod_is_error_free_in_range() {
    let mut rng = Rng::new(0x1002);
    for case in 0..CASES {
        let a = rng.spread_f32(-40, 40);
        let b = rng.spread_f32(-40, 40);
        let (x, y) = ff::two_prod(a, b);
        if !x.is_finite() || (y != 0.0 && y.abs() < f32::MIN_POSITIVE * 4.0) {
            continue; // overflow / subnormal error term (excluded, §6.1)
        }
        assert_eq!(
            x as f64 + y as f64,
            a as f64 * b as f64,
            "case {case}: two_prod({a:e}, {b:e})"
        );
    }
}

#[test]
fn prop_split_parts_recombine_and_fit() {
    let mut rng = Rng::new(0x1003);
    for case in 0..CASES {
        let a = adversarial_f32(&mut rng);
        for (hi, lo) in [ff::split(a), ff::split_dekker(a)] {
            if !hi.is_finite() {
                continue; // dekker splitter can overflow at the extreme
            }
            assert_eq!(hi as f64 + lo as f64, a as f64, "case {case}: split({a:e})");
            // non-overlap: hi's ulp granularity covers lo's magnitude
            if hi != 0.0 && lo != 0.0 {
                assert!(
                    lo.abs() as f64 <= ffgpu::util::ulp_f32(hi) * 4096.0,
                    "case {case}: overlap split({a:e}) -> ({hi:e}, {lo:e})"
                );
            }
        }
    }
}

#[test]
fn prop_ff32_add_mul_error_bounds() {
    let mut rng = Rng::new(0x1004);
    for case in 0..CASES {
        let (ah, al) = rng.ff_pair(-10, 10);
        let (bh, bl) = rng.ff_pair(-10, 10);
        let a = FF32::from_parts(ah, al);
        let b = FF32::from_parts(bh, bl);
        let (a64, b64) = (a.to_f64(), b.to_f64());

        let sum = a + b;
        let sum_err = (sum.to_f64() - (a64 + b64)).abs();
        let sum_bound = (2f64.powi(-23) * (al as f64 + bl as f64).abs())
            .max(2f64.powi(-43) * (a64 + b64).abs());
        assert!(sum_err <= sum_bound + 1e-300, "case {case}: add22 {a:?} {b:?}");

        let prod = a * b;
        if prod.is_finite() && a64 * b64 != 0.0 {
            let rel = ((prod.to_f64() - a64 * b64) / (a64 * b64)).abs();
            assert!(rel <= 2f64.powi(-43), "case {case}: mul22 {a:?} {b:?} rel={rel:e}");
        }
    }
}

#[test]
fn prop_ff32_results_stay_normalised() {
    let mut rng = Rng::new(0x1005);
    for case in 0..CASES {
        let (ah, al) = rng.ff_pair(-12, 12);
        let (bh, bl) = rng.ff_pair(-12, 12);
        let a = FF32::from_parts(ah, al);
        let b = FF32::from_parts(bh, bl);
        for (tag, r) in [("add", a + b), ("sub", a - b), ("mul", a * b)] {
            if r.is_finite() {
                assert!(r.is_normalised(), "case {case} {tag}: {r:?}");
            }
        }
    }
}

#[test]
fn prop_dyadic_ring_axioms() {
    let mut rng = Rng::new(0x1006);
    for case in 0..20_000 {
        let a = Dyadic::from_f32(adversarial_f32(&mut rng));
        let b = Dyadic::from_f32(adversarial_f32(&mut rng));
        let c = Dyadic::from_f32(adversarial_f32(&mut rng));
        // commutativity
        assert_eq!(a.add(&b).cmp(&b.add(&a)), std::cmp::Ordering::Equal, "case {case}");
        assert_eq!(a.mul(&b).cmp(&b.mul(&a)), std::cmp::Ordering::Equal, "case {case}");
        // associativity (exact arithmetic!)
        let l = a.add(&b).add(&c);
        let r = a.add(&b.add(&c));
        assert_eq!(l.cmp(&r), std::cmp::Ordering::Equal, "case {case}");
        // distributivity
        let l = a.mul(&b.add(&c));
        let r = a.mul(&b).add(&a.mul(&c));
        assert_eq!(l.cmp(&r), std::cmp::Ordering::Equal, "case {case}");
        // sub/neg coherence
        assert!(a.sub(&a).is_zero(), "case {case}");
    }
}

#[test]
fn prop_biguint_mul_matches_division_back() {
    let mut rng = Rng::new(0x1007);
    for case in 0..10_000 {
        let a = BigUint::from_u128(((rng.next_u64() as u128) << 32) | rng.next_u64() as u128);
        let b = BigUint::from_u64(rng.next_u64() | 1);
        let p = a.mul(&b);
        // p has bits(a)+bits(b) or one less
        let bits = p.bits();
        assert!(
            bits == a.bits() + b.bits() || bits + 1 == a.bits() + b.bits(),
            "case {case}: bits {bits} vs {} + {}", a.bits(), b.bits()
        );
        // (a*b) >> k << k == a*b when k <= trailing zeros
        let tz = p.trailing_zeros();
        assert_eq!(p.shr(tz).shl(tz), p, "case {case}");
    }
}

#[test]
fn prop_gpusim_ieee_matches_hardware() {
    // the IEEE-configured simulator must agree with actual f32 hardware
    // on every operation — the strongest check that the datapath
    // emulation (alignment, guard, sticky, RNE) is exactly right.
    let m = GpuModel::IEEE;
    let mut rng = Rng::new(0x1008);
    for case in 0..CASES {
        let a = rng.spread_f32(-30, 30);
        let b = rng.spread_f32(-30, 30);
        let qa = m.quantize(a as f64);
        let qb = m.quantize(b as f64);
        assert_eq!(m.to_f64(m.add(qa, qb)), (a + b) as f64, "case {case}: {a:e}+{b:e}");
        assert_eq!(m.to_f64(m.sub(qa, qb)), (a - b) as f64, "case {case}: {a:e}-{b:e}");
        assert_eq!(m.to_f64(m.mul(qa, qb)), (a * b) as f64, "case {case}: {a:e}*{b:e}");
    }
}

#[test]
fn prop_gpusim_add12_exact_under_guard_bit() {
    // Th. 2 under the paper's Nvidia assumption, random search
    let m = GpuModel::NV35;
    let mut rng = Rng::new(0x1009);
    let mut inexact = 0u32;
    for _ in 0..CASES {
        let a = m.quantize(rng.spread_f32(-10, 10) as f64);
        let b = m.quantize(rng.spread_f32(-10, 10) as f64);
        let (s, r) = sim::add12(&m, a, b);
        if m.to_f64(s) + m.to_f64(r) != m.to_f64(a) + m.to_f64(b) {
            inexact += 1;
        }
    }
    // truncated-with-guard addition: rare sub-ulp residuals only
    assert!((inexact as f64) / (CASES as f64) < 0.02, "inexact={inexact}");
}

#[test]
fn prop_batcher_plan_covers_exactly() {
    let sizes = [4096usize, 16384, 65536, 262144, 1048576];
    let mut rng = Rng::new(0x100A);
    for case in 0..20_000 {
        let total = 1 + rng.below(3_000_000);
        let plan = batcher::plan(total, &sizes).unwrap();
        // launches tile [0, total) contiguously
        let mut pos = 0usize;
        for l in &plan {
            assert_eq!(l.start, pos, "case {case}: gap in plan {plan:?}");
            assert!(l.len <= l.size, "case {case}");
            assert!(sizes.contains(&l.size), "case {case}");
            pos += l.len;
        }
        assert_eq!(pos, total, "case {case}: plan covers {pos} of {total}");
        // waste is bounded: only the tail pads (one launch, or two when
        // the split tail wins), and padding stays below the largest size
        let padding: usize = plan.iter().map(|l| l.size - l.len).sum();
        assert!(padding < 1048576, "case {case}: padding {padding}");
        assert!(
            plan.iter().filter(|l| l.len < l.size).count() <= 2,
            "case {case}: more than a split tail padded: {plan:?}"
        );
        // the split tail never pads more than the old greedy single
        // tail (the smallest size fitting the remainder) would have
        let head: usize = (total / 1048576) * 1048576;
        let remaining = total - head;
        if remaining > 0 {
            let single = *sizes.iter().find(|&&s| s >= remaining).unwrap();
            let single_waste = single - remaining;
            assert!(
                padding <= single_waste,
                "case {case}: split tail pads {padding}, single tail {single_waste}"
            );
        }
    }
}

#[test]
fn prop_compensated_sum_within_bound() {
    let mut rng = Rng::new(0x100B);
    for case in 0..2_000 {
        let n = 10 + rng.below(3000);
        let data: Vec<f32> = (0..n).map(|_| adversarial_f32(&mut rng) * 1e-10).collect();
        let want: f64 = data.iter().map(|&v| v as f64).sum();
        let got = ff::compensated::sum2(&data) as f64;
        let scale: f64 = data.iter().map(|&v| (v as f64).abs()).sum();
        // Sum2 bound: |err| <= eps|sum| + O(n eps^2) * scale
        let bound = 2f64.powi(-24) * want.abs()
            + (n * n) as f64 * 2f64.powi(-48) * scale
            + 1e-300;
        assert!(
            (got - want).abs() <= bound * 4.0,
            "case {case}: n={n} err={:e} bound={bound:e}", (got - want).abs()
        );
    }
}
